//! CSR (compressed sparse row) f32 matrix.

/// Immutable CSR matrix.  Column indices within each row are kept sorted
/// (the builder sorts and merges duplicates by summing).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row r spans `indptr[r]..indptr[r+1]` in `indices`/`values`.
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatrix {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Raw `[a, b)` window into the nnz arrays — the absolute ranges a
    /// [`BlockSliceIndex`] hands out.  Crate-internal: only the kernel
    /// layer (`sparse::simd`) walks nnz storage directly.
    pub(crate) fn nnz_slices(&self, a: usize, b: usize) -> (&[u32], &[f32]) {
        (&self.indices[a..b], &self.values[a..b])
    }

    /// y = A x.  The inner dot product runs four independent
    /// accumulators so LLVM keeps separate FMA chains in flight (the
    /// single-accumulator form serializes on the add latency).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let (idx, vals) = self.row(r);
            let n = idx.len();
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let mut k = 0;
            while k + 4 <= n {
                a0 += vals[k] * x[idx[k] as usize];
                a1 += vals[k + 1] * x[idx[k + 1] as usize];
                a2 += vals[k + 2] * x[idx[k + 2] as usize];
                a3 += vals[k + 3] * x[idx[k + 3] as usize];
                k += 4;
            }
            let mut acc = (a0 + a1) + (a2 + a3);
            while k < n {
                acc += vals[k] * x[idx[k] as usize];
                k += 1;
            }
            y[r] = acc;
        }
    }

    /// g += A^T s (accumulating; caller zeroes g when needed).  4-wide
    /// unrolled scatter; accumulation order per target element is
    /// unchanged (row order, then within-row order), so results stay
    /// bit-identical with the block-sliced kernel.
    pub fn tmatvec_acc(&self, s: &[f32], g: &mut [f32]) {
        assert_eq!(s.len(), self.rows);
        assert_eq!(g.len(), self.cols);
        for r in 0..self.rows {
            let sr = s[r];
            if sr == 0.0 {
                continue;
            }
            let (idx, vals) = self.row(r);
            scatter_acc(idx, vals, sr, 0, g);
        }
    }

    /// Like `tmatvec_acc` but only accumulating columns in
    /// `[col_lo, col_hi)`, writing into `g[0..col_hi-col_lo]`.  Kept as
    /// the index-free reference: per row it binary-searches for the
    /// block start and scans to the block end — O(rows·log nnz_row +
    /// nnz-in-range).  The hot path uses [`CsrMatrix::tmatvec_block_sliced`]
    /// with a precomputed [`BlockSliceIndex`] instead.
    pub fn tmatvec_block_acc(&self, s: &[f32], col_lo: usize, col_hi: usize, g: &mut [f32]) {
        assert!(col_lo <= col_hi && col_hi <= self.cols);
        assert_eq!(g.len(), col_hi - col_lo);
        let (lo32, hi32) = (col_lo as u32, col_hi as u32);
        for r in 0..self.rows {
            let sr = s[r];
            if sr == 0.0 {
                continue;
            }
            let (idx, vals) = self.row(r);
            let start = idx.partition_point(|&j| j < lo32);
            let end = start + idx[start..].partition_point(|&j| j < hi32);
            scatter_acc(&idx[start..end], &vals[start..end], sr, lo32, g);
        }
    }

    /// Build the per-(block, row) nonzero-range index for a matrix whose
    /// columns are grouped into contiguous blocks of `block_size` (the
    /// packed per-worker layout).  One pass over the nnz; built once at
    /// shard construction.
    ///
    /// `block_size` need not divide `cols`: the last block is then a
    /// trailing partial block of `cols % block_size` columns
    /// ([`BlockSliceIndex::block_len`]).
    pub fn block_slices(&self, block_size: usize) -> BlockSliceIndex {
        assert!(block_size > 0, "block_size must be positive");
        assert!(self.cols > 0, "block_slices of a zero-column matrix");
        assert!(self.nnz() <= u32::MAX as usize, "nnz exceeds u32 index range");
        let n_blocks = self.cols.div_ceil(block_size);
        let mut cuts = Vec::with_capacity(self.rows * (n_blocks + 1));
        for r in 0..self.rows {
            let (start, end) = (self.indptr[r], self.indptr[r + 1]);
            let idx = &self.indices[start..end];
            let mut k = 0usize;
            for b in 0..n_blocks {
                // Invariant: k = #indices in this row with column < b·db.
                cuts.push((start + k) as u32);
                let hi = ((b + 1) * block_size) as u32;
                while k < idx.len() && idx[k] < hi {
                    k += 1;
                }
            }
            cuts.push(end as u32);
        }
        BlockSliceIndex { n_blocks, block_size, rows: self.rows, cols: self.cols, cuts }
    }

    /// Block-gradient kernel over a precomputed [`BlockSliceIndex`]:
    /// `g += (A^T s)[block·db .. (block+1)·db]` as a tight loop over
    /// exactly the in-block nonzeros — no per-row binary search, no scan
    /// past the block end.
    pub fn tmatvec_block_sliced(
        &self,
        s: &[f32],
        index: &BlockSliceIndex,
        block: usize,
        g: &mut [f32],
    ) {
        assert_eq!(s.len(), self.rows);
        assert_eq!(index.rows, self.rows, "index built for a different matrix");
        assert!(block < index.n_blocks);
        assert_eq!(g.len(), index.block_len(block));
        let lo = (block * index.block_size) as u32;
        let stride = index.n_blocks + 1;
        for r in 0..self.rows {
            let sr = s[r];
            if sr == 0.0 {
                continue;
            }
            let a = index.cuts[r * stride + block] as usize;
            let b = index.cuts[r * stride + block + 1] as usize;
            scatter_acc(&self.indices[a..b], &self.values[a..b], sr, lo, g);
        }
    }

    /// Sub-matrix of a contiguous row range (cheap copy of slices).
    pub fn row_slice(&self, lo: usize, hi: usize) -> CsrMatrix {
        assert!(lo <= hi && hi <= self.rows);
        let (a, b) = (self.indptr[lo], self.indptr[hi]);
        CsrMatrix {
            rows: hi - lo,
            cols: self.cols,
            indptr: self.indptr[lo..=hi].iter().map(|p| p - a).collect(),
            indices: self.indices[a..b].to_vec(),
            values: self.values[a..b].to_vec(),
        }
    }

    /// Sub-matrix keeping rows listed in `rows` (in the given order).
    pub fn select_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut b = CsrBuilder::new(rows.len(), self.cols);
        for (new_r, &r) in rows.iter().enumerate() {
            let (idx, vals) = self.row(r);
            for (&j, &v) in idx.iter().zip(vals) {
                b.push(new_r, j as usize, v);
            }
        }
        b.build()
    }

    /// Remap columns: new matrix with `new_cols` columns where old column
    /// `j` becomes `map[j]` (u32::MAX = drop).  Used to pack a worker's
    /// active feature blocks into contiguous slots.
    pub fn remap_cols(&self, map: &[u32], new_cols: usize) -> CsrMatrix {
        assert_eq!(map.len(), self.cols);
        let mut b = CsrBuilder::new(self.rows, new_cols);
        for r in 0..self.rows {
            let (idx, vals) = self.row(r);
            for (&j, &v) in idx.iter().zip(vals) {
                let nj = map[j as usize];
                if nj != u32::MAX {
                    b.push(r, nj as usize, v);
                }
            }
        }
        b.build()
    }

    /// Densify a row range into a row-major buffer of shape
    /// (hi-lo, cols), zero-filled.
    pub fn densify_rows(&self, lo: usize, hi: usize, out: &mut [f32]) {
        assert_eq!(out.len(), (hi - lo) * self.cols);
        out.fill(0.0);
        for r in lo..hi {
            let (idx, vals) = self.row(r);
            let base = (r - lo) * self.cols;
            for (&j, &v) in idx.iter().zip(vals) {
                out[base + j as usize] = v;
            }
        }
    }

    /// Column-usage histogram (for partitioner stats / tests).
    pub fn col_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.cols];
        for &j in &self.indices {
            c[j as usize] += 1;
        }
        c
    }

    /// Max column index actually used + 1 (0 if empty).
    pub fn max_used_col(&self) -> usize {
        self.indices.iter().map(|&j| j as usize + 1).max().unwrap_or(0)
    }

    /// Per-row squared l2 norm; `sum_r max_j a_rj^2`-style bounds feed the
    /// Lipschitz estimates in `admm::penalty`.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).1.iter().map(|v| v * v).sum())
            .collect()
    }
}

/// `g[idx[k] - base] += vals[k] * sr`, 4-wide unrolled.  Element order is
/// preserved (pure unroll), so callers composing it see identical f32
/// results to the naive loop.  Crate-visible: `sparse::simd` dispatches
/// to this as the `unrolled` scatter kernel.
#[inline]
pub(crate) fn scatter_acc(idx: &[u32], vals: &[f32], sr: f32, base: u32, g: &mut [f32]) {
    let n = idx.len();
    let mut k = 0;
    while k + 4 <= n {
        g[(idx[k] - base) as usize] += vals[k] * sr;
        g[(idx[k + 1] - base) as usize] += vals[k + 1] * sr;
        g[(idx[k + 2] - base) as usize] += vals[k + 2] * sr;
        g[(idx[k + 3] - base) as usize] += vals[k + 3] * sr;
        k += 4;
    }
    while k < n {
        g[(idx[k] - base) as usize] += vals[k] * sr;
        k += 1;
    }
}

/// Per-(block, row) nonzero ranges of a packed CSR matrix whose columns
/// form `n_blocks` contiguous blocks of `block_size` — the precomputed
/// index behind [`CsrMatrix::tmatvec_block_sliced`].
///
/// Layout: `cuts` has `rows * (n_blocks + 1)` entries;
/// `cuts[r*(n_blocks+1) + b]` is the absolute nnz offset where block b's
/// entries begin in row r, and `cuts[r*(n_blocks+1) + n_blocks]` is the
/// row end — so block b of row r spans `cuts[..b] .. cuts[..b+1]`.
/// Offsets are `u32` (the builder caps matrices at u32 nnz), keeping the
/// index at 4·rows·(n_blocks+1) bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockSliceIndex {
    n_blocks: usize,
    block_size: usize,
    rows: usize,
    cols: usize,
    cuts: Vec<u32>,
}

impl BlockSliceIndex {
    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns actually covered by `block`: `block_size` everywhere
    /// except a trailing partial block when `block_size` does not
    /// divide the matrix's column count.
    pub fn block_len(&self, block: usize) -> usize {
        assert!(block < self.n_blocks);
        (self.cols - block * self.block_size).min(self.block_size)
    }

    /// Nonzeros of `block` within row `r` as an absolute `[start, end)`
    /// range into the matrix's nnz arrays.
    pub fn row_range(&self, r: usize, block: usize) -> (usize, usize) {
        let stride = self.n_blocks + 1;
        (self.cuts[r * stride + block] as usize, self.cuts[r * stride + block + 1] as usize)
    }

    /// Total nonzeros falling inside `block` (index-only statistic).
    pub fn block_nnz(&self, block: usize) -> usize {
        (0..self.rows)
            .map(|r| {
                let (a, b) = self.row_range(r, block);
                b - a
            })
            .sum()
    }
}

/// Triplet accumulator -> CSR.  Duplicates are summed; per-row column
/// indices come out sorted.
#[derive(Debug)]
pub struct CsrBuilder {
    rows: usize,
    cols: usize,
    triplets: Vec<(u32, u32, f32)>,
}

impl CsrBuilder {
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(cols <= u32::MAX as usize && rows <= u32::MAX as usize);
        CsrBuilder { rows, cols, triplets: Vec::new() }
    }

    pub fn push(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols, "({r},{c}) out of ({},{})", self.rows, self.cols);
        self.triplets.push((r as u32, c as u32, v));
    }

    pub fn build(mut self) -> CsrMatrix {
        self.triplets.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(self.triplets.len());
        let mut values: Vec<f32> = Vec::with_capacity(self.triplets.len());
        let mut last: Option<(u32, u32)> = None;
        for &(r, c, v) in &self.triplets {
            if last == Some((r, c)) {
                *values.last_mut().unwrap() += v;
            } else {
                indptr[r as usize + 1] += 1;
                indices.push(c);
                values.push(v);
                last = Some((r, c));
            }
        }
        for r in 0..self.rows {
            indptr[r + 1] += indptr[r];
        }
        CsrMatrix { rows: self.rows, cols: self.cols, indptr, indices, values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::dense;
    use crate::util::rng::Rng;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> (CsrMatrix, Vec<f32>) {
        let mut b = CsrBuilder::new(rows, cols);
        let mut d = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    let v = rng.normal_f32(0.0, 1.0);
                    b.push(r, c, v);
                    d[r * cols + c] = v;
                }
            }
        }
        (b.build(), d)
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::new(1);
        let (a, d) = random_csr(&mut rng, 23, 17, 0.3);
        let x: Vec<f32> = (0..17).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut y = vec![0.0; 23];
        a.matvec(&x, &mut y);
        let yd = dense::matvec(&d, 23, 17, &x);
        for (u, v) in y.iter().zip(&yd) {
            assert!((u - v).abs() < 1e-4, "{u} vs {v}");
        }
    }

    #[test]
    fn tmatvec_matches_dense() {
        let mut rng = Rng::new(2);
        let (a, d) = random_csr(&mut rng, 31, 9, 0.4);
        let s: Vec<f32> = (0..31).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut g = vec![0.0; 9];
        a.tmatvec_acc(&s, &mut g);
        let gd = dense::tmatvec(&d, 31, 9, &s);
        for (u, v) in g.iter().zip(&gd) {
            assert!((u - v).abs() < 1e-4);
        }
    }

    #[test]
    fn tmatvec_block_matches_full_slice() {
        let mut rng = Rng::new(3);
        let (a, _) = random_csr(&mut rng, 40, 24, 0.25);
        let s: Vec<f32> = (0..40).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut full = vec![0.0; 24];
        a.tmatvec_acc(&s, &mut full);
        for (lo, hi) in [(0, 8), (8, 16), (16, 24), (4, 20)] {
            let mut blk = vec![0.0; hi - lo];
            a.tmatvec_block_acc(&s, lo, hi, &mut blk);
            for (k, g) in blk.iter().enumerate() {
                assert!((g - full[lo + k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn block_slices_cover_every_nonzero_exactly_once() {
        let mut rng = Rng::new(7);
        let (a, _) = random_csr(&mut rng, 33, 24, 0.3);
        for db in [4usize, 8, 12, 24] {
            let ix = a.block_slices(db);
            assert_eq!(ix.n_blocks(), 24 / db);
            assert_eq!(ix.rows(), 33);
            let covered: usize = (0..ix.n_blocks()).map(|b| ix.block_nnz(b)).sum();
            assert_eq!(covered, a.nnz(), "db={db}");
            // Ranges tile each row in order.
            for r in 0..33 {
                let (row_lo, _) = ix.row_range(r, 0);
                let (_, row_hi) = ix.row_range(r, ix.n_blocks() - 1);
                let mut expect = row_lo;
                for b in 0..ix.n_blocks() {
                    let (lo, hi) = ix.row_range(r, b);
                    assert_eq!(lo, expect);
                    assert!(hi >= lo);
                    expect = hi;
                }
                assert_eq!(expect, row_hi);
            }
        }
    }

    #[test]
    fn tmatvec_block_sliced_matches_scan_kernel_exactly() {
        let mut rng = Rng::new(8);
        let (a, _) = random_csr(&mut rng, 40, 32, 0.25);
        let s: Vec<f32> = (0..40).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let db = 8;
        let ix = a.block_slices(db);
        for b in 0..4 {
            let mut scan = vec![0.0f32; db];
            a.tmatvec_block_acc(&s, b * db, (b + 1) * db, &mut scan);
            let mut sliced = vec![0.0f32; db];
            a.tmatvec_block_sliced(&s, &ix, b, &mut sliced);
            // Same accumulation order => bit-identical, not just close.
            assert_eq!(scan, sliced, "block {b}");
        }
    }

    #[test]
    fn block_slices_handle_empty_rows_and_blocks() {
        let mut b = CsrBuilder::new(3, 8);
        b.push(0, 1, 1.0); // row 1 empty; block 1 (cols 4..8) only row 2
        b.push(2, 6, 2.0);
        let m = b.build();
        let ix = m.block_slices(4);
        assert_eq!(ix.block_nnz(0), 1);
        assert_eq!(ix.block_nnz(1), 1);
        assert_eq!(ix.row_range(1, 0), ix.row_range(1, 1)); // empty row
        let s = [1.0f32, 1.0, 3.0];
        let mut g = vec![0.0f32; 4];
        m.tmatvec_block_sliced(&s, &ix, 1, &mut g);
        assert_eq!(g, vec![0.0, 0.0, 6.0, 0.0]);
    }

    #[test]
    fn block_slices_trailing_partial_block() {
        // cols=10, db=4 -> blocks of 4, 4, 2: the last block is partial
        // and every nonzero (including one in the very last column)
        // must still be covered exactly once.
        let mut rng = Rng::new(21);
        let (a, _) = random_csr(&mut rng, 19, 10, 0.4);
        let ix = a.block_slices(4);
        assert_eq!(ix.n_blocks(), 3);
        assert_eq!(ix.block_len(0), 4);
        assert_eq!(ix.block_len(1), 4);
        assert_eq!(ix.block_len(2), 2);
        let covered: usize = (0..3).map(|b| ix.block_nnz(b)).sum();
        assert_eq!(covered, a.nnz());
        // The sliced gradient over the partial block matches the
        // index-free scan bit for bit.
        let s: Vec<f32> = (0..19).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut scan = vec![0.0f32; 2];
        a.tmatvec_block_acc(&s, 8, 10, &mut scan);
        let mut sliced = vec![0.0f32; 2];
        a.tmatvec_block_sliced(&s, &ix, 2, &mut sliced);
        assert_eq!(scan, sliced);
        // Full-width blocks are unaffected by the relaxed geometry.
        let mut scan0 = vec![0.0f32; 4];
        a.tmatvec_block_acc(&s, 0, 4, &mut scan0);
        let mut sliced0 = vec![0.0f32; 4];
        a.tmatvec_block_sliced(&s, &ix, 0, &mut sliced0);
        assert_eq!(scan0, sliced0);
    }

    #[test]
    fn block_slices_block_size_larger_than_cols() {
        // Degenerate but legal: one partial block spanning everything.
        let mut rng = Rng::new(22);
        let (a, _) = random_csr(&mut rng, 9, 5, 0.5);
        let ix = a.block_slices(8);
        assert_eq!(ix.n_blocks(), 1);
        assert_eq!(ix.block_len(0), 5);
        assert_eq!(ix.block_nnz(0), a.nnz());
        let s: Vec<f32> = (0..9).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut full = vec![0.0f32; 5];
        a.tmatvec_acc(&s, &mut full);
        let mut sliced = vec![0.0f32; 5];
        a.tmatvec_block_sliced(&s, &ix, 0, &mut sliced);
        assert_eq!(full, sliced);
    }

    #[test]
    fn block_slices_all_empty_column_block() {
        // Middle block (cols 4..8) has no nonzeros at all: its ranges
        // must be empty for every row and its gradient must be a no-op,
        // while the flanking blocks stay intact.
        let mut b = CsrBuilder::new(4, 12);
        b.push(0, 0, 1.0);
        b.push(1, 2, 2.0);
        b.push(2, 9, 3.0);
        b.push(3, 11, 4.0);
        let m = b.build();
        let ix = m.block_slices(4);
        assert_eq!(ix.n_blocks(), 3);
        assert_eq!(ix.block_nnz(1), 0);
        for r in 0..4 {
            let (lo, hi) = ix.row_range(r, 1);
            assert_eq!(lo, hi, "row {r} has phantom nnz in the empty block");
        }
        let s = [1.0f32, 1.0, 2.0, 0.5];
        let mut g = vec![0.7f32; 4];
        m.tmatvec_block_sliced(&s, &ix, 1, &mut g);
        assert_eq!(g, vec![0.7; 4]); // untouched accumulator
        let mut g2 = vec![0.0f32; 4];
        m.tmatvec_block_sliced(&s, &ix, 2, &mut g2);
        assert_eq!(g2, vec![0.0, 6.0, 0.0, 2.0]);
    }

    #[test]
    fn builder_sums_duplicates_and_sorts() {
        let mut b = CsrBuilder::new(2, 4);
        b.push(0, 3, 1.0);
        b.push(0, 1, 2.0);
        b.push(0, 3, 0.5);
        b.push(1, 0, -1.0);
        let m = b.build();
        assert_eq!(m.nnz(), 3);
        let (idx, vals) = m.row(0);
        assert_eq!(idx, &[1, 3]);
        assert_eq!(vals, &[2.0, 1.5]);
        assert_eq!(m.row(1), (&[0u32][..], &[-1.0f32][..]));
    }

    #[test]
    fn row_slice_preserves_content() {
        let mut rng = Rng::new(4);
        let (a, _) = random_csr(&mut rng, 20, 10, 0.3);
        let s = a.row_slice(5, 12);
        assert_eq!(s.rows(), 7);
        for r in 0..7 {
            assert_eq!(s.row(r), a.row(r + 5));
        }
    }

    #[test]
    fn select_rows_reorders() {
        let mut b = CsrBuilder::new(3, 2);
        b.push(0, 0, 1.0);
        b.push(1, 1, 2.0);
        b.push(2, 0, 3.0);
        let m = b.build();
        let sel = m.select_rows(&[2, 0]);
        assert_eq!(sel.row(0), (&[0u32][..], &[3.0f32][..]));
        assert_eq!(sel.row(1), (&[0u32][..], &[1.0f32][..]));
    }

    #[test]
    fn remap_cols_packs_and_drops() {
        let mut b = CsrBuilder::new(2, 6);
        b.push(0, 0, 1.0);
        b.push(0, 4, 2.0);
        b.push(1, 5, 3.0);
        let m = b.build();
        // keep cols {4,5} -> {0,1}, drop the rest
        let mut map = vec![u32::MAX; 6];
        map[4] = 0;
        map[5] = 1;
        let p = m.remap_cols(&map, 2);
        assert_eq!(p.cols(), 2);
        assert_eq!(p.row(0), (&[0u32][..], &[2.0f32][..]));
        assert_eq!(p.row(1), (&[1u32][..], &[3.0f32][..]));
    }

    #[test]
    fn densify_rows_roundtrip() {
        let mut rng = Rng::new(5);
        let (a, d) = random_csr(&mut rng, 8, 6, 0.5);
        let mut out = vec![0.0f32; 8 * 6];
        a.densify_rows(0, 8, &mut out);
        assert_eq!(out, d);
        // partial range
        let mut part = vec![0.0f32; 3 * 6];
        a.densify_rows(2, 5, &mut part);
        assert_eq!(part, d[12..30].to_vec());
    }

    #[test]
    fn col_counts_and_norms() {
        let mut b = CsrBuilder::new(2, 3);
        b.push(0, 0, 3.0);
        b.push(0, 2, 4.0);
        b.push(1, 2, 1.0);
        let m = b.build();
        assert_eq!(m.col_counts(), vec![1, 0, 2]);
        assert_eq!(m.row_sq_norms(), vec![25.0, 1.0]);
        assert_eq!(m.max_used_col(), 3);
    }
}

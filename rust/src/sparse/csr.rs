//! CSR (compressed sparse row) f32 matrix.

/// Immutable CSR matrix.  Column indices within each row are kept sorted
/// (the builder sorts and merges duplicates by summing).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row r spans `indptr[r]..indptr[r+1]` in `indices`/`values`.
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatrix {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let (idx, vals) = self.row(r);
            let mut acc = 0.0f32;
            for (&j, &v) in idx.iter().zip(vals) {
                acc += v * x[j as usize];
            }
            y[r] = acc;
        }
    }

    /// g += A^T s (accumulating; caller zeroes g when needed).
    pub fn tmatvec_acc(&self, s: &[f32], g: &mut [f32]) {
        assert_eq!(s.len(), self.rows);
        assert_eq!(g.len(), self.cols);
        for r in 0..self.rows {
            let sr = s[r];
            if sr == 0.0 {
                continue;
            }
            let (idx, vals) = self.row(r);
            for (&j, &v) in idx.iter().zip(vals) {
                g[j as usize] += v * sr;
            }
        }
    }

    /// Like `tmatvec_acc` but only accumulating columns in
    /// `[col_lo, col_hi)`, writing into `g[0..col_hi-col_lo]`.  This is
    /// the native block-gradient kernel: indices are sorted per row, so a
    /// binary search bounds the scan.
    pub fn tmatvec_block_acc(&self, s: &[f32], col_lo: usize, col_hi: usize, g: &mut [f32]) {
        assert!(col_lo <= col_hi && col_hi <= self.cols);
        assert_eq!(g.len(), col_hi - col_lo);
        let (lo32, hi32) = (col_lo as u32, col_hi as u32);
        for r in 0..self.rows {
            let sr = s[r];
            if sr == 0.0 {
                continue;
            }
            let (idx, vals) = self.row(r);
            let start = idx.partition_point(|&j| j < lo32);
            for k in start..idx.len() {
                let j = idx[k];
                if j >= hi32 {
                    break;
                }
                g[(j - lo32) as usize] += vals[k] * sr;
            }
        }
    }

    /// Sub-matrix of a contiguous row range (cheap copy of slices).
    pub fn row_slice(&self, lo: usize, hi: usize) -> CsrMatrix {
        assert!(lo <= hi && hi <= self.rows);
        let (a, b) = (self.indptr[lo], self.indptr[hi]);
        CsrMatrix {
            rows: hi - lo,
            cols: self.cols,
            indptr: self.indptr[lo..=hi].iter().map(|p| p - a).collect(),
            indices: self.indices[a..b].to_vec(),
            values: self.values[a..b].to_vec(),
        }
    }

    /// Sub-matrix keeping rows listed in `rows` (in the given order).
    pub fn select_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut b = CsrBuilder::new(rows.len(), self.cols);
        for (new_r, &r) in rows.iter().enumerate() {
            let (idx, vals) = self.row(r);
            for (&j, &v) in idx.iter().zip(vals) {
                b.push(new_r, j as usize, v);
            }
        }
        b.build()
    }

    /// Remap columns: new matrix with `new_cols` columns where old column
    /// `j` becomes `map[j]` (u32::MAX = drop).  Used to pack a worker's
    /// active feature blocks into contiguous slots.
    pub fn remap_cols(&self, map: &[u32], new_cols: usize) -> CsrMatrix {
        assert_eq!(map.len(), self.cols);
        let mut b = CsrBuilder::new(self.rows, new_cols);
        for r in 0..self.rows {
            let (idx, vals) = self.row(r);
            for (&j, &v) in idx.iter().zip(vals) {
                let nj = map[j as usize];
                if nj != u32::MAX {
                    b.push(r, nj as usize, v);
                }
            }
        }
        b.build()
    }

    /// Densify a row range into a row-major buffer of shape
    /// (hi-lo, cols), zero-filled.
    pub fn densify_rows(&self, lo: usize, hi: usize, out: &mut [f32]) {
        assert_eq!(out.len(), (hi - lo) * self.cols);
        out.fill(0.0);
        for r in lo..hi {
            let (idx, vals) = self.row(r);
            let base = (r - lo) * self.cols;
            for (&j, &v) in idx.iter().zip(vals) {
                out[base + j as usize] = v;
            }
        }
    }

    /// Column-usage histogram (for partitioner stats / tests).
    pub fn col_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.cols];
        for &j in &self.indices {
            c[j as usize] += 1;
        }
        c
    }

    /// Max column index actually used + 1 (0 if empty).
    pub fn max_used_col(&self) -> usize {
        self.indices.iter().map(|&j| j as usize + 1).max().unwrap_or(0)
    }

    /// Per-row squared l2 norm; `sum_r max_j a_rj^2`-style bounds feed the
    /// Lipschitz estimates in `admm::penalty`.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).1.iter().map(|v| v * v).sum())
            .collect()
    }
}

/// Triplet accumulator -> CSR.  Duplicates are summed; per-row column
/// indices come out sorted.
#[derive(Debug)]
pub struct CsrBuilder {
    rows: usize,
    cols: usize,
    triplets: Vec<(u32, u32, f32)>,
}

impl CsrBuilder {
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(cols <= u32::MAX as usize && rows <= u32::MAX as usize);
        CsrBuilder { rows, cols, triplets: Vec::new() }
    }

    pub fn push(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols, "({r},{c}) out of ({},{})", self.rows, self.cols);
        self.triplets.push((r as u32, c as u32, v));
    }

    pub fn build(mut self) -> CsrMatrix {
        self.triplets.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(self.triplets.len());
        let mut values: Vec<f32> = Vec::with_capacity(self.triplets.len());
        let mut last: Option<(u32, u32)> = None;
        for &(r, c, v) in &self.triplets {
            if last == Some((r, c)) {
                *values.last_mut().unwrap() += v;
            } else {
                indptr[r as usize + 1] += 1;
                indices.push(c);
                values.push(v);
                last = Some((r, c));
            }
        }
        for r in 0..self.rows {
            indptr[r + 1] += indptr[r];
        }
        CsrMatrix { rows: self.rows, cols: self.cols, indptr, indices, values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::dense;
    use crate::util::rng::Rng;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> (CsrMatrix, Vec<f32>) {
        let mut b = CsrBuilder::new(rows, cols);
        let mut d = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    let v = rng.normal_f32(0.0, 1.0);
                    b.push(r, c, v);
                    d[r * cols + c] = v;
                }
            }
        }
        (b.build(), d)
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::new(1);
        let (a, d) = random_csr(&mut rng, 23, 17, 0.3);
        let x: Vec<f32> = (0..17).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut y = vec![0.0; 23];
        a.matvec(&x, &mut y);
        let yd = dense::matvec(&d, 23, 17, &x);
        for (u, v) in y.iter().zip(&yd) {
            assert!((u - v).abs() < 1e-4, "{u} vs {v}");
        }
    }

    #[test]
    fn tmatvec_matches_dense() {
        let mut rng = Rng::new(2);
        let (a, d) = random_csr(&mut rng, 31, 9, 0.4);
        let s: Vec<f32> = (0..31).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut g = vec![0.0; 9];
        a.tmatvec_acc(&s, &mut g);
        let gd = dense::tmatvec(&d, 31, 9, &s);
        for (u, v) in g.iter().zip(&gd) {
            assert!((u - v).abs() < 1e-4);
        }
    }

    #[test]
    fn tmatvec_block_matches_full_slice() {
        let mut rng = Rng::new(3);
        let (a, _) = random_csr(&mut rng, 40, 24, 0.25);
        let s: Vec<f32> = (0..40).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut full = vec![0.0; 24];
        a.tmatvec_acc(&s, &mut full);
        for (lo, hi) in [(0, 8), (8, 16), (16, 24), (4, 20)] {
            let mut blk = vec![0.0; hi - lo];
            a.tmatvec_block_acc(&s, lo, hi, &mut blk);
            for (k, g) in blk.iter().enumerate() {
                assert!((g - full[lo + k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn builder_sums_duplicates_and_sorts() {
        let mut b = CsrBuilder::new(2, 4);
        b.push(0, 3, 1.0);
        b.push(0, 1, 2.0);
        b.push(0, 3, 0.5);
        b.push(1, 0, -1.0);
        let m = b.build();
        assert_eq!(m.nnz(), 3);
        let (idx, vals) = m.row(0);
        assert_eq!(idx, &[1, 3]);
        assert_eq!(vals, &[2.0, 1.5]);
        assert_eq!(m.row(1), (&[0u32][..], &[-1.0f32][..]));
    }

    #[test]
    fn row_slice_preserves_content() {
        let mut rng = Rng::new(4);
        let (a, _) = random_csr(&mut rng, 20, 10, 0.3);
        let s = a.row_slice(5, 12);
        assert_eq!(s.rows(), 7);
        for r in 0..7 {
            assert_eq!(s.row(r), a.row(r + 5));
        }
    }

    #[test]
    fn select_rows_reorders() {
        let mut b = CsrBuilder::new(3, 2);
        b.push(0, 0, 1.0);
        b.push(1, 1, 2.0);
        b.push(2, 0, 3.0);
        let m = b.build();
        let sel = m.select_rows(&[2, 0]);
        assert_eq!(sel.row(0), (&[0u32][..], &[3.0f32][..]));
        assert_eq!(sel.row(1), (&[0u32][..], &[1.0f32][..]));
    }

    #[test]
    fn remap_cols_packs_and_drops() {
        let mut b = CsrBuilder::new(2, 6);
        b.push(0, 0, 1.0);
        b.push(0, 4, 2.0);
        b.push(1, 5, 3.0);
        let m = b.build();
        // keep cols {4,5} -> {0,1}, drop the rest
        let mut map = vec![u32::MAX; 6];
        map[4] = 0;
        map[5] = 1;
        let p = m.remap_cols(&map, 2);
        assert_eq!(p.cols(), 2);
        assert_eq!(p.row(0), (&[0u32][..], &[2.0f32][..]));
        assert_eq!(p.row(1), (&[1u32][..], &[3.0f32][..]));
    }

    #[test]
    fn densify_rows_roundtrip() {
        let mut rng = Rng::new(5);
        let (a, d) = random_csr(&mut rng, 8, 6, 0.5);
        let mut out = vec![0.0f32; 8 * 6];
        a.densify_rows(0, 8, &mut out);
        assert_eq!(out, d);
        // partial range
        let mut part = vec![0.0f32; 3 * 6];
        a.densify_rows(2, 5, &mut part);
        assert_eq!(part, d[12..30].to_vec());
    }

    #[test]
    fn col_counts_and_norms() {
        let mut b = CsrBuilder::new(2, 3);
        b.push(0, 0, 3.0);
        b.push(0, 2, 4.0);
        b.push(1, 2, 1.0);
        let m = b.build();
        assert_eq!(m.col_counts(), vec![1, 0, 2]);
        assert_eq!(m.row_sq_norms(), vec![25.0, 1.0]);
        assert_eq!(m.max_used_col(), 3);
    }
}

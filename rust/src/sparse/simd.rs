//! Explicit SIMD kernel layer with one-time runtime dispatch
//! (DESIGN.md §2.0.4, ROADMAP item 4).
//!
//! Three implementation families of the five hot-path kernels — spmv
//! ([`CsrMatrix::matvec`]), the block gradient
//! ([`CsrMatrix::tmatvec_block_sliced`]), its scatter primitive, the
//! server prox ([`crate::admm::prox_l1_box`]) and the w̃-sum update
//! ([`crate::admm::add_assign_diff`]) — behind a [`Kernels`] dispatch
//! table of plain fn pointers, selected **once** at session start from
//! `--set kernel=scalar|unrolled|simd|auto`:
//!
//! * `scalar` — naive one-element loops, the differential reference.
//! * `unrolled` — the 4-wide hand-unrolled loops shipped by PRs 1–5
//!   (LLVM autovectorizes them; portable to every ISA).
//! * `simd` — explicit AVX2 `std::arch` intrinsics (this module).
//!   Resolves to `unrolled` at dispatch time when the host lacks AVX2 —
//!   the returned table's `name` reports what actually runs, so tests
//!   can assert the fallback was *taken*, not silently passed.
//!
//! ## Bit-identity contract
//!
//! **FMA is deliberately not used anywhere in this module.**  A fused
//! multiply-add rounds once where `mul` + `add` round twice, which would
//! break the repo's exact `to_bits()` gates against the scalar
//! references; every AVX2 kernel here composes only singly-rounded ops
//! (`mul`/`add`/`sub`/`div`/`min`/`max` and bitwise sign ops), in the
//! same per-element order as its reference, so for all finite inputs:
//!
//! * `prox_l1_box`, `add_assign_diff`, `scatter_acc`, and
//!   `tmatvec_block_sliced` are bit-identical across **all three**
//!   families (element-wise, or element-order-preserving scatter).
//! * `matvec` reduces with the unrolled kernel's exact 4-accumulator
//!   association (lane k sums elements `i % 4 == k`, combined as
//!   `(a0+a1)+(a2+a3)`), so `simd` is bit-identical to `unrolled`; the
//!   single-accumulator `scalar` form is a *different* (also exact)
//!   association and agrees to normal f32 dot-product tolerance.
//!
//! NaN payloads may differ between families (e.g. the sign-transfer
//! soft-threshold maps NaN to ±0 where scalar propagates it); no finite
//! training input produces NaN ahead of the kernels, and the gates run
//! finite inputs only.
#![deny(clippy::undocumented_unsafe_blocks)]

use crate::config::KernelKind;
use crate::sparse::csr::scatter_acc as scatter_acc_unrolled;
use crate::sparse::{BlockSliceIndex, CsrMatrix};

/// Whether the explicit-SIMD table can run on this host.  The detection
/// macro caches in an atomic, so calling this per kernel invocation (the
/// defensive guard in the wrappers) costs one relaxed load.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// One resolved family of hot-path kernels.  Plain `fn` pointers in a
/// `'static` table: selection happens once (`Kernels::select`), the hot
/// path pays one indirect call per *kernel invocation* (thousands of
/// elements), never per element.
#[derive(Clone, Copy)]
pub struct Kernels {
    /// The family that actually runs (`"scalar" | "unrolled" | "simd"`)
    /// — after fallback resolution, so it may differ from the requested
    /// [`KernelKind`].
    pub name: &'static str,
    /// `y = A x` over CSR.
    pub matvec: fn(&CsrMatrix, &[f32], &mut [f32]),
    /// `g += (A^T s)[block]` over a precomputed [`BlockSliceIndex`].
    pub tmatvec_block_sliced: fn(&CsrMatrix, &[f32], &BlockSliceIndex, usize, &mut [f32]),
    /// `g[idx[k]-base] += vals[k] * sr`.
    pub scatter_acc: fn(&[u32], &[f32], f32, u32, &mut [f32]),
    /// Eq. 13 prox: `(z_tilde, w_sum, gamma, denom, lambda, clip, out)`.
    pub prox_l1_box: fn(&[f32], &[f32], f32, f32, f32, f32, &mut [f32]),
    /// Incremental w̃-sum: `sum[k] += new[k] - old[k]`.
    pub add_assign_diff: fn(&mut [f32], &[f32], &[f32]),
}

impl std::fmt::Debug for Kernels {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Kernels").field("name", &self.name).finish()
    }
}

impl Kernels {
    /// Resolve a config choice to the table that will actually run:
    /// `auto` prefers `simd`, and `simd` on a non-AVX2 host falls back
    /// to `unrolled` (reflected in [`Kernels::name`]).
    pub fn select(kind: KernelKind) -> &'static Kernels {
        match kind {
            KernelKind::Scalar => &SCALAR,
            KernelKind::Unrolled => &UNROLLED,
            KernelKind::Simd | KernelKind::Auto => {
                #[cfg(target_arch = "x86_64")]
                if simd_available() {
                    return &SIMD;
                }
                &UNROLLED
            }
        }
    }

    /// The default table (`kernel=auto`): SIMD when the host has it.
    pub fn auto() -> &'static Kernels {
        Self::select(KernelKind::Auto)
    }
}

pub static SCALAR: Kernels = Kernels {
    name: "scalar",
    matvec: matvec_scalar,
    tmatvec_block_sliced: tmatvec_block_sliced_scalar,
    scatter_acc: scatter_acc_scalar,
    prox_l1_box: crate::admm::prox_l1_box_scalar,
    add_assign_diff: crate::admm::add_assign_diff_scalar,
};

pub static UNROLLED: Kernels = Kernels {
    name: "unrolled",
    matvec: matvec_unrolled,
    tmatvec_block_sliced: tmatvec_block_sliced_unrolled,
    scatter_acc: scatter_acc_unrolled,
    prox_l1_box: crate::admm::prox_l1_box,
    add_assign_diff: crate::admm::add_assign_diff,
};

#[cfg(target_arch = "x86_64")]
pub static SIMD: Kernels = Kernels {
    name: "simd",
    matvec: matvec_simd,
    tmatvec_block_sliced: tmatvec_block_sliced_simd,
    scatter_acc: scatter_acc_simd,
    prox_l1_box: prox_l1_box_simd,
    add_assign_diff: add_assign_diff_simd,
};

// ---------------------------------------------------------------------------
// scalar family — naive loops, the differential reference
// ---------------------------------------------------------------------------

/// Single-accumulator spmv: the plain textbook loop.  NOT bit-identical
/// to the 4-accumulator `unrolled`/`simd` reduction (different exact
/// association); agrees to dot-product tolerance.
fn matvec_scalar(a: &CsrMatrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.cols());
    assert_eq!(y.len(), a.rows());
    for r in 0..a.rows() {
        let (idx, vals) = a.row(r);
        let mut acc = 0.0f32;
        for (&j, &v) in idx.iter().zip(vals) {
            acc += v * x[j as usize];
        }
        y[r] = acc;
    }
}

fn scatter_acc_scalar(idx: &[u32], vals: &[f32], sr: f32, base: u32, g: &mut [f32]) {
    for (&j, &v) in idx.iter().zip(vals) {
        g[(j - base) as usize] += v * sr;
    }
}

fn tmatvec_block_sliced_scalar(
    a: &CsrMatrix,
    s: &[f32],
    index: &BlockSliceIndex,
    block: usize,
    g: &mut [f32],
) {
    tmatvec_block_sliced_with(a, s, index, block, g, scatter_acc_scalar)
}

// ---------------------------------------------------------------------------
// unrolled family — delegates to the existing 4-wide kernels
// ---------------------------------------------------------------------------

fn matvec_unrolled(a: &CsrMatrix, x: &[f32], y: &mut [f32]) {
    a.matvec(x, y)
}

fn tmatvec_block_sliced_unrolled(
    a: &CsrMatrix,
    s: &[f32],
    index: &BlockSliceIndex,
    block: usize,
    g: &mut [f32],
) {
    a.tmatvec_block_sliced(s, index, block, g)
}

/// Shared block-gradient skeleton: the row loop, zero-skip, and slice
/// lookup are identical across families — only the scatter primitive
/// differs.  Mirrors [`CsrMatrix::tmatvec_block_sliced`] exactly.
fn tmatvec_block_sliced_with(
    a: &CsrMatrix,
    s: &[f32],
    index: &BlockSliceIndex,
    block: usize,
    g: &mut [f32],
    scatter: fn(&[u32], &[f32], f32, u32, &mut [f32]),
) {
    assert_eq!(s.len(), a.rows());
    assert_eq!(index.rows(), a.rows(), "index built for a different matrix");
    assert!(block < index.n_blocks());
    assert_eq!(g.len(), index.block_len(block));
    let lo = (block * index.block_size()) as u32;
    for r in 0..a.rows() {
        let sr = s[r];
        if sr == 0.0 {
            continue;
        }
        let (start, end) = index.row_range(r, block);
        let (idx, vals) = a.nnz_slices(start, end);
        scatter(idx, vals, sr, lo, g);
    }
}

// ---------------------------------------------------------------------------
// simd family — explicit AVX2, x86_64 only
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    /// Safe wrapper: verifies the AVX2 precondition before entering the
    /// `#[target_feature]` body.  The fallback branch makes the raw fn
    /// pointer safe to call even off-table (it costs one cached atomic
    /// load); `Kernels::select` never hands out this table without AVX2.
    pub(super) fn matvec_simd(a: &CsrMatrix, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), a.cols());
        assert_eq!(y.len(), a.rows());
        // The 32-bit gather reads indices as *signed*; CSR cols are
        // capped at u32::MAX by the builder, so reject the upper half.
        assert!(a.cols() <= i32::MAX as usize, "matvec_simd: cols exceed i32 gather range");
        if !simd_available() {
            return matvec_unrolled(a, x, y);
        }
        // SAFETY: AVX2 availability checked just above.
        unsafe { matvec_avx2(a, x, y) }
    }

    /// 4-lane spmv replicating the unrolled kernel's exact reduction:
    /// lane k accumulates elements `i % 4 == k` with one mul + one add
    /// per element (no FMA), lanes combined `(a0+a1)+(a2+a3)` — so the
    /// result is bit-identical to [`CsrMatrix::matvec`].
    ///
    /// SAFETY (caller): requires AVX2.  All memory accesses are in
    /// bounds: `k + 4 <= n` guards the 16-byte index/value loads, and
    /// gather offsets are CSR column indices `< cols == x.len()`
    /// (checked `<= i32::MAX` by the wrapper, so they stay positive as
    /// i32).
    #[target_feature(enable = "avx2")]
    unsafe fn matvec_avx2(a: &CsrMatrix, x: &[f32], y: &mut [f32]) {
        for r in 0..a.rows() {
            let (idx, vals) = a.row(r);
            let n = idx.len();
            let mut acc = _mm_setzero_ps();
            let mut k = 0usize;
            while k + 4 <= n {
                let v = _mm_loadu_ps(vals.as_ptr().add(k));
                let ix = _mm_loadu_si128(idx.as_ptr().add(k) as *const __m128i);
                let gathered = _mm_i32gather_ps::<4>(x.as_ptr(), ix);
                acc = _mm_add_ps(acc, _mm_mul_ps(v, gathered));
                k += 4;
            }
            let mut lanes = [0.0f32; 4];
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
            let mut sum = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
            while k < n {
                sum += vals[k] * x[idx[k] as usize];
                k += 1;
            }
            y[r] = sum;
        }
    }

    pub(super) fn scatter_acc_simd(idx: &[u32], vals: &[f32], sr: f32, base: u32, g: &mut [f32]) {
        if !simd_available() {
            return scatter_acc_unrolled(idx, vals, sr, base, g);
        }
        // SAFETY: AVX2 availability checked just above.
        unsafe { scatter_acc_avx2(idx, vals, sr, base, g) }
    }

    /// AVX2 has no scatter instruction, so the vectorizable half — the
    /// `vals[k] * sr` products — runs 8-wide into a stack temp and the
    /// indexed accumulates stay scalar.  Each product rounds once
    /// (identical to scalar) and the adds run in element order, so the
    /// result is bit-identical to both references.
    ///
    /// SAFETY (caller): requires AVX2; `k + 8 <= n` guards the 32-byte
    /// value loads.
    #[target_feature(enable = "avx2")]
    unsafe fn scatter_acc_avx2(idx: &[u32], vals: &[f32], sr: f32, base: u32, g: &mut [f32]) {
        let n = idx.len();
        let srv = _mm256_set1_ps(sr);
        let mut prod = [0.0f32; 8];
        let mut k = 0usize;
        while k + 8 <= n {
            let v = _mm256_loadu_ps(vals.as_ptr().add(k));
            _mm256_storeu_ps(prod.as_mut_ptr(), _mm256_mul_ps(v, srv));
            for (j, &p) in prod.iter().enumerate() {
                g[(idx[k + j] - base) as usize] += p;
            }
            k += 8;
        }
        while k < n {
            g[(idx[k] - base) as usize] += vals[k] * sr;
            k += 1;
        }
    }

    pub(super) fn tmatvec_block_sliced_simd(
        a: &CsrMatrix,
        s: &[f32],
        index: &BlockSliceIndex,
        block: usize,
        g: &mut [f32],
    ) {
        tmatvec_block_sliced_with(a, s, index, block, g, scatter_acc_simd)
    }

    pub(super) fn prox_l1_box_simd(
        z_tilde: &[f32],
        w_sum: &[f32],
        gamma: f32,
        denom: f32,
        lambda: f32,
        clip: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(z_tilde.len(), w_sum.len());
        debug_assert_eq!(z_tilde.len(), out.len());
        debug_assert!(denom > 0.0);
        if !simd_available() {
            return crate::admm::prox_l1_box(z_tilde, w_sum, gamma, denom, lambda, clip, out);
        }
        // SAFETY: AVX2 availability checked just above.
        unsafe { prox_avx2(z_tilde, w_sum, gamma, denom, lambda, clip, out) }
    }

    /// 8-wide Eq. 13 prox.  Per element, in reference order:
    /// `v = (γ·z̃ + w)/denom` (mul, add, div — the division is kept, not
    /// reciprocal-multiplied), `t = max(|v| - thr, 0)`, sign-of-`v`
    /// transferred onto `t` (exactly `signum(v) * t` for finite `v`),
    /// then `min(max(·, -clip), clip)` which matches `f32::clamp` for
    /// finite inputs.  Every step rounds exactly like the scalar
    /// reference ⇒ bit-identical.
    ///
    /// SAFETY (caller): requires AVX2; `k + 8 <= n` guards all 32-byte
    /// loads/stores, and the three slices have equal length (debug-
    /// asserted by the wrapper, guaranteed by the server call sites).
    #[target_feature(enable = "avx2")]
    unsafe fn prox_avx2(
        z_tilde: &[f32],
        w_sum: &[f32],
        gamma: f32,
        denom: f32,
        lambda: f32,
        clip: f32,
        out: &mut [f32],
    ) {
        let thr = lambda / denom;
        let n = out.len();
        let gv = _mm256_set1_ps(gamma);
        let dv = _mm256_set1_ps(denom);
        let tv = _mm256_set1_ps(thr);
        let hi = _mm256_set1_ps(clip);
        let lo = _mm256_set1_ps(-clip);
        let zero = _mm256_setzero_ps();
        let sign_mask = _mm256_set1_ps(-0.0);
        let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        let mut k = 0usize;
        while k + 8 <= n {
            let zt = _mm256_loadu_ps(z_tilde.as_ptr().add(k));
            let ws = _mm256_loadu_ps(w_sum.as_ptr().add(k));
            let v = _mm256_div_ps(_mm256_add_ps(_mm256_mul_ps(gv, zt), ws), dv);
            let soft = _mm256_or_ps(
                _mm256_max_ps(_mm256_sub_ps(_mm256_and_ps(v, abs_mask), tv), zero),
                _mm256_and_ps(v, sign_mask),
            );
            let clamped = _mm256_min_ps(_mm256_max_ps(soft, lo), hi);
            _mm256_storeu_ps(out.as_mut_ptr().add(k), clamped);
            k += 8;
        }
        for i in k..n {
            let v = (gamma * z_tilde[i] + w_sum[i]) / denom;
            out[i] = crate::admm::soft_threshold(v, thr).clamp(-clip, clip);
        }
    }

    pub(super) fn add_assign_diff_simd(sum: &mut [f32], new: &[f32], old: &[f32]) {
        debug_assert_eq!(sum.len(), new.len());
        debug_assert_eq!(sum.len(), old.len());
        if !simd_available() {
            return crate::admm::add_assign_diff(sum, new, old);
        }
        // SAFETY: AVX2 availability checked just above.
        unsafe { add_assign_diff_avx2(sum, new, old) }
    }

    /// 8-wide `sum[k] += new[k] - old[k]`: one sub + one add per
    /// element, same order as scalar ⇒ bit-identical.
    ///
    /// SAFETY (caller): requires AVX2; `k + 8 <= n` guards all 32-byte
    /// loads/stores, slice lengths equal per the wrapper.
    #[target_feature(enable = "avx2")]
    unsafe fn add_assign_diff_avx2(sum: &mut [f32], new: &[f32], old: &[f32]) {
        let n = sum.len();
        let mut k = 0usize;
        while k + 8 <= n {
            let s = _mm256_loadu_ps(sum.as_ptr().add(k));
            let nv = _mm256_loadu_ps(new.as_ptr().add(k));
            let ov = _mm256_loadu_ps(old.as_ptr().add(k));
            _mm256_storeu_ps(sum.as_mut_ptr().add(k), _mm256_add_ps(s, _mm256_sub_ps(nv, ov)));
            k += 8;
        }
        for i in k..n {
            sum[i] += new[i] - old[i];
        }
    }
}

#[cfg(target_arch = "x86_64")]
use avx2::{
    add_assign_diff_simd, matvec_simd, prox_l1_box_simd, scatter_acc_simd,
    tmatvec_block_sliced_simd,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CsrBuilder;
    use crate::util::rng::Rng;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> CsrMatrix {
        let mut b = CsrBuilder::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    b.push(r, c, rng.normal_f32(0.0, 1.0));
                }
            }
        }
        b.build()
    }

    #[allow(unused_mut)]
    fn families() -> Vec<&'static Kernels> {
        let mut fams = vec![&SCALAR, &UNROLLED];
        #[cfg(target_arch = "x86_64")]
        if simd_available() {
            fams.push(&SIMD);
        }
        fams
    }

    #[test]
    fn select_resolves_fallbacks_by_name() {
        assert_eq!(Kernels::select(KernelKind::Scalar).name, "scalar");
        assert_eq!(Kernels::select(KernelKind::Unrolled).name, "unrolled");
        let expect = if simd_available() { "simd" } else { "unrolled" };
        // `simd` on a non-AVX2 host must RESOLVE to unrolled (visible in
        // the name), not die at first kernel call.
        assert_eq!(Kernels::select(KernelKind::Simd).name, expect);
        assert_eq!(Kernels::select(KernelKind::Auto).name, expect);
        assert_eq!(Kernels::auto().name, expect);
    }

    #[test]
    fn scatter_and_block_gradient_bit_identical_across_all_families() {
        // scatter_acc preserves element order in every family, so the
        // whole tmatvec composition must be exactly equal — scalar too.
        let mut rng = Rng::new(0x51D);
        for (rows, cols, db) in [(37usize, 24usize, 8usize), (64, 96, 32), (11, 20, 7)] {
            let a = random_csr(&mut rng, rows, cols, 0.3);
            let ix = a.block_slices(db);
            let s: Vec<f32> = (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            for b in 0..ix.n_blocks() {
                let mut reference = vec![0.1f32; ix.block_len(b)];
                (SCALAR.tmatvec_block_sliced)(&a, &s, &ix, b, &mut reference);
                for fam in families() {
                    let mut g = vec![0.1f32; ix.block_len(b)];
                    (fam.tmatvec_block_sliced)(&a, &s, &ix, b, &mut g);
                    for (k, (x, y)) in g.iter().zip(&reference).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "{} block-grad diverged at block {b} elem {k}",
                            fam.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn simd_matvec_bit_identical_to_unrolled() {
        let mut rng = Rng::new(0xA7);
        for (rows, cols) in [(23usize, 17usize), (40, 64), (7, 129)] {
            let a = random_csr(&mut rng, rows, cols, 0.35);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut y_unrolled = vec![0.0f32; rows];
            (UNROLLED.matvec)(&a, &x, &mut y_unrolled);
            if simd_available() {
                #[cfg(target_arch = "x86_64")]
                {
                    let mut y_simd = vec![0.0f32; rows];
                    (SIMD.matvec)(&a, &x, &mut y_simd);
                    for (k, (u, v)) in y_simd.iter().zip(&y_unrolled).enumerate() {
                        assert_eq!(u.to_bits(), v.to_bits(), "simd matvec row {k}: {u} vs {v}");
                    }
                }
            }
            // scalar uses a different exact association: tolerance gate.
            let mut y_scalar = vec![0.0f32; rows];
            (SCALAR.matvec)(&a, &x, &mut y_scalar);
            for (u, v) in y_scalar.iter().zip(&y_unrolled) {
                assert!((u - v).abs() <= 1e-4 * (1.0 + v.abs()), "{u} vs {v}");
            }
        }
    }

    #[test]
    fn simd_prox_and_wsum_bit_identical_to_scalar_all_lengths() {
        // Same discipline as admm::prox's unrolled-vs-scalar gates:
        // every remainder length, randomized parameters, exact bits.
        let mut rng = Rng::new(0xBEEF);
        let fams = families();
        for db in [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 64, 257] {
            for _ in 0..20 {
                let zt: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 3.0)).collect();
                let ws: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 3.0)).collect();
                let gamma = rng.f32() * 2.0;
                let denom = 0.1 + rng.f32() * 20.0;
                let lambda = rng.f32();
                let clip = 0.5 + rng.f32() * 4.0;
                let mut reference = vec![0.0f32; db];
                (SCALAR.prox_l1_box)(&zt, &ws, gamma, denom, lambda, clip, &mut reference);
                let base: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                let mut ref_sum = base.clone();
                (SCALAR.add_assign_diff)(&mut ref_sum, &zt, &ws);
                for fam in &fams {
                    let mut out = vec![0.0f32; db];
                    (fam.prox_l1_box)(&zt, &ws, gamma, denom, lambda, clip, &mut out);
                    for (a, b) in out.iter().zip(&reference) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{} prox db={db}", fam.name);
                    }
                    let mut sum = base.clone();
                    (fam.add_assign_diff)(&mut sum, &zt, &ws);
                    for (a, b) in sum.iter().zip(&ref_sum) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{} w-sum db={db}", fam.name);
                    }
                }
            }
        }
    }

    #[test]
    fn simd_prox_preserves_sign_of_zero() {
        // soft_threshold keeps the input's sign on a zero output
        // (signum(-x)·0 = -0.0); the SIMD sign-transfer must agree bit
        // for bit, which plain `==` would not catch.
        let zt = [0.2f32, -0.2, 0.0, -0.0, 1e-30, -1e-30, 5.0, -5.0];
        let ws = [0.0f32; 8];
        for fam in families() {
            let mut out = [7.0f32; 8];
            let mut reference = [7.0f32; 8];
            // thr = 1.0/1.0 swallows everything but ±5.0.
            (fam.prox_l1_box)(&zt, &ws, 1.0, 1.0, 1.0, 100.0, &mut out);
            (SCALAR.prox_l1_box)(&zt, &ws, 1.0, 1.0, 1.0, 100.0, &mut reference);
            for (k, (a, b)) in out.iter().zip(&reference).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} elem {k}: {a} vs {b}", fam.name);
            }
        }
    }

    #[test]
    fn standalone_scatter_matches_across_families() {
        let mut rng = Rng::new(0x5CA7);
        for n in [0usize, 1, 3, 7, 8, 9, 16, 33] {
            let idx: Vec<u32> = (0..n as u32).map(|k| 100 + k * 2).collect();
            let vals: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let base_g: Vec<f32> = (0..80).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut reference = base_g.clone();
            (SCALAR.scatter_acc)(&idx, &vals, 1.7, 100, &mut reference);
            for fam in families() {
                let mut g = base_g.clone();
                (fam.scatter_acc)(&idx, &vals, 1.7, 100, &mut g);
                for (a, b) in g.iter().zip(&reference) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{} n={n}", fam.name);
                }
            }
        }
    }
}

//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! Implements xoshiro256++ (Blackman & Vigna) seeded via SplitMix64, plus
//! the distributions the data generator and coordinator need: uniform
//! ranges, Bernoulli, Box-Muller normals, bounded Zipf (power-law feature
//! frequencies for the synthetic KDDa-like dataset), Fisher-Yates shuffle
//! and sampling without replacement.
//!
//! Everything in the repo that needs randomness takes an explicit `&mut
//! Rng` so experiments are reproducible from a single seed recorded in the
//! report header.

/// xoshiro256++ PRNG. Deterministic, 2^256-1 period, splittable by
/// re-seeding from `next_u64`.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed via SplitMix64 so that low-entropy seeds (0, 1, 2, ...) still
    /// produce well-distributed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (used to give each worker its
    /// own deterministic stream from the experiment seed).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Lemire's method without bias for the
    /// sizes used here (n << 2^64, modulo bias < 2^-40 — fine for
    /// simulation; tests only rely on coverage, not exact uniformity).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (caches the spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0.
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (sin, cos) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.spare_normal = Some(r * sin);
        r * cos
    }

    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Used by the delay
    /// injector and the DES arrival processes.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from [0, n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher-Yates over an index vec; O(n) memory is fine at
        // the scales used (feature counts fit easily).
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Bounded Zipf sampler over {0, .., n-1} with exponent `s` (probability
/// of rank r proportional to 1/(r+1)^s). Uses the classic
/// inverse-transform-with-rejection scheme (Devroye / as in rand_distr),
/// O(1) per sample after O(1) setup.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: f64,
    s: f64,
    t: f64,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1);
        assert!(s >= 0.0);
        let n = n as f64;
        let t = if (s - 1.0).abs() < 1e-9 {
            1.0 + n.ln()
        } else {
            (n.powf(1.0 - s) - s) / (1.0 - s)
        };
        Zipf { n, s, t }
    }

    /// Inverse of the dominating distribution's CDF.
    fn inv_cdf(&self, p: f64) -> f64 {
        let pt = p * self.t;
        if pt <= 1.0 {
            pt
        } else if (self.s - 1.0).abs() < 1e-9 {
            (pt - 1.0).exp()
        } else {
            (1.0 + pt * (1.0 - self.s)).powf(1.0 / (1.0 - self.s))
        }
    }

    /// Sample a rank in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        loop {
            let p = 1.0 - rng.f64(); // (0, 1]
            let x = self.inv_cdf(p);
            let k = x.ceil().max(1.0).min(self.n);
            // Acceptance test (k within [x, x+1) region).
            let q = if (self.s - 1.0).abs() < 1e-9 {
                k / (k + 1.0) * x.max(1.0) / k
            } else {
                (k / (k + 1.0)).powf(self.s - 1.0) * x.max(1.0).powf(self.s) / k.powf(self.s)
            };
            if rng.f64() < q {
                return (k as usize) - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::new(17);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            counts[k] += 1;
        }
        // Rank 0 must dominate rank 100 heavily under s=1.1.
        assert!(counts[0] > 20 * counts[100].max(1), "{} vs {}", counts[0], counts[100]);
        // Tail still gets occasional mass.
        assert!(counts[500..].iter().sum::<usize>() > 0);
    }

    #[test]
    fn split_streams_are_independent_ish() {
        let mut parent = Rng::new(1);
        let mut a = parent.split();
        let mut b = parent.split();
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}

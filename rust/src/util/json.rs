//! Minimal JSON substrate (no `serde`/`serde_json` available offline).
//!
//! Supports exactly what the repo needs: parsing `artifacts/manifest.json`
//! (objects, arrays, strings, numbers, bools, null) and emitting report
//! files.  Strict enough to reject malformed documents with a position in
//! the error; not a general-purpose streaming parser.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field helpers that produce good error messages.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field {key:?}"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field {key:?}"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field {key:?}"))
    }

    // -- writer ----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, v)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, key);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: advance over one code point.
                    let start = self.i;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Convenience constructors for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.req_arr("a").unwrap();
        assert_eq!(arr[2].req_str("b").unwrap(), "x");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"unterminated", "{} extra"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries": [{"name": "ws", "db": 16, "ok": true, "x": 1.25}], "v": 1}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string_pretty();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn usize_accessor_rejects_fractions() {
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("3").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn unicode_string_roundtrip() {
        let v = Json::parse("\"caf\\u00e9 ×\"").unwrap();
        assert_eq!(v.as_str(), Some("café ×"));
    }

    #[test]
    fn manifest_like_doc() {
        let doc = r#"{"version": 1, "entries": [
            {"name": "server_prox_16", "file": "server_prox_16.hlo.txt",
             "db": 16, "inputs": [{"shape": [16], "dtype": "float32"}]}]}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.req_usize("version").unwrap(), 1);
        let e = &v.req_arr("entries").unwrap()[0];
        assert_eq!(e.req_str("file").unwrap(), "server_prox_16.hlo.txt");
        assert_eq!(
            e.req_arr("inputs").unwrap()[0].req_arr("shape").unwrap()[0].as_usize(),
            Some(16)
        );
    }
}

//! Cache-line-aligned memory primitives for the coordinator hot path.
//!
//! Two false-sharing sources motivated this module (DESIGN.md §2.0.4):
//!
//! * **Per-block hot state.**  `BlockTable` keeps one small
//!   mutex + counter bundle per consensus block; adjacent blocks land on
//!   the same 64-byte line, so two server threads servicing *different*
//!   blocks still ping-pong the line.  [`CacheAligned`] pads every entry
//!   to its own line.
//! * **Pooled push buffers.**  `Vec<f32>` is 4-byte aligned; two pooled
//!   w-buffers can share a line boundary, and the SIMD kernels prefer
//!   (though do not require) 32-byte-aligned loads.  [`AlignedBuf`] is an
//!   owned f32 buffer whose storage always starts on a 64-byte boundary.
#![deny(clippy::undocumented_unsafe_blocks)]

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// 64 bytes: one cache line on every x86_64 and most aarch64 hosts.
pub const CACHE_LINE: usize = 64;

/// Pads (and aligns) `T` to a full cache line so adjacent array elements
/// never share one.  `Deref`s to `T`, so wrapping is transparent at use
/// sites: `CacheAligned(Mutex::new(state))`.
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct CacheAligned<T>(pub T);

impl<T> Deref for CacheAligned<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T> DerefMut for CacheAligned<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}

/// Owned, fixed-length f32 buffer whose storage is 64-byte aligned.
///
/// `Vec<f32>` cannot guarantee alignment beyond 4 bytes (and re-aligning
/// one in place is UB), so the push-buffer pool owns these instead: a raw
/// allocation with an explicit 64-byte [`Layout`], `Deref`ing to `[f32]`
/// so every consumer keeps slice ergonomics.  Zero-length buffers (the
/// `Default` used by `PushMsg::recycle_now`'s `mem::take`) allocate
/// nothing.
#[derive(Debug)]
pub struct AlignedBuf {
    ptr: NonNull<f32>,
    len: usize,
}

// SAFETY: AlignedBuf uniquely owns its allocation (no aliasing, no
// interior mutability); moving it between threads is as safe as moving a
// Vec<f32>.
unsafe impl Send for AlignedBuf {}
// SAFETY: &AlignedBuf only exposes &[f32]; shared reads are safe.
unsafe impl Sync for AlignedBuf {}

impl AlignedBuf {
    fn layout(len: usize) -> Layout {
        Layout::from_size_align(len * std::mem::size_of::<f32>(), CACHE_LINE)
            .expect("AlignedBuf size overflow")
    }

    /// A zero-filled buffer of `len` f32s on its own cache line(s).
    pub fn zeroed(len: usize) -> Self {
        if len == 0 {
            return AlignedBuf { ptr: NonNull::dangling(), len: 0 };
        }
        let layout = Self::layout(len);
        // SAFETY: layout has non-zero size (len > 0 checked above) and
        // valid 64-byte power-of-two alignment.
        let raw = unsafe { alloc_zeroed(layout) };
        let Some(ptr) = NonNull::new(raw as *mut f32) else {
            handle_alloc_error(layout);
        };
        AlignedBuf { ptr, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Default for AlignedBuf {
    fn default() -> Self {
        AlignedBuf::zeroed(0)
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: ptr was produced by alloc_zeroed with exactly this
            // layout (len is immutable after construction) and is only
            // freed here, once.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len)) };
        }
    }
}

impl Deref for AlignedBuf {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        // SAFETY: ptr is valid for len f32 reads (or dangling with
        // len == 0, for which from_raw_parts is defined), and the buffer
        // outlives the borrow.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl DerefMut for AlignedBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: as above, plus &mut self guarantees exclusivity.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Clone for AlignedBuf {
    fn clone(&self) -> Self {
        let mut b = AlignedBuf::zeroed(self.len);
        b.copy_from_slice(self);
        b
    }
}

impl From<Vec<f32>> for AlignedBuf {
    fn from(v: Vec<f32>) -> Self {
        let mut b = AlignedBuf::zeroed(v.len());
        b.copy_from_slice(&v);
        b
    }
}

impl From<&[f32]> for AlignedBuf {
    fn from(v: &[f32]) -> Self {
        let mut b = AlignedBuf::zeroed(v.len());
        b.copy_from_slice(v);
        b
    }
}

impl PartialEq for AlignedBuf {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<Vec<f32>> for AlignedBuf {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<AlignedBuf> for Vec<f32> {
    fn eq(&self, other: &AlignedBuf) -> bool {
        self[..] == other[..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_aligned_is_line_sized_and_aligned() {
        assert_eq!(std::mem::align_of::<CacheAligned<u8>>(), CACHE_LINE);
        assert_eq!(std::mem::size_of::<CacheAligned<u8>>(), CACHE_LINE);
        let xs: [CacheAligned<u64>; 4] = Default::default();
        for x in &xs {
            assert_eq!(&x.0 as *const u64 as usize % CACHE_LINE, 0);
        }
    }

    #[test]
    fn aligned_buf_is_zeroed_aligned_and_writable() {
        for len in [1usize, 4, 7, 64, 513] {
            let mut b = AlignedBuf::zeroed(len);
            assert_eq!(b.len(), len);
            assert_eq!(b.as_ptr() as usize % CACHE_LINE, 0, "len={len}");
            assert!(b.iter().all(|&x| x == 0.0));
            b[len - 1] = 3.5;
            assert_eq!(b[len - 1], 3.5);
        }
    }

    #[test]
    fn aligned_buf_empty_default_clone_eq() {
        let empty = AlignedBuf::default();
        assert!(empty.is_empty());
        let b: AlignedBuf = vec![1.0f32, 2.0, 3.0].into();
        let c = b.clone();
        assert_eq!(b, c);
        assert_eq!(b, vec![1.0, 2.0, 3.0]);
        assert_ne!(b, vec![1.0, 2.0]);
        // mem::take (the recycle path) leaves a harmless empty buffer.
        let mut m = b;
        let taken = std::mem::take(&mut m);
        assert_eq!(taken.len(), 3);
        assert!(m.is_empty());
    }

    #[test]
    fn adjacent_pool_buffers_never_share_a_line() {
        let bufs: Vec<AlignedBuf> = (0..8).map(|_| AlignedBuf::zeroed(3)).collect();
        let mut lines: Vec<usize> = bufs.iter().map(|b| b.as_ptr() as usize / CACHE_LINE).collect();
        lines.sort_unstable();
        lines.dedup();
        assert_eq!(lines.len(), 8, "two 3-float buffers landed on one line");
    }
}

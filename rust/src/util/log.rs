//! Minimal leveled logger (no `env_logger` offline). Level from
//! `ASYBADMM_LOG` (error|warn|info|debug|trace), default `info`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);
// std-only lazy init (the offline build has no `once_cell`).
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let parsed = match std::env::var("ASYBADMM_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override programmatically (tests, quiet benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if (l as u8) > level() {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

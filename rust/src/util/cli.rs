//! Tiny CLI argument substrate (no `clap` available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, and auto-generated `--help` from registered options.  Used
//! by the `asybadmm` binary and all examples so every entry point has a
//! consistent, discoverable interface.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Opt {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument parser.
#[derive(Debug, Default)]
pub struct Args {
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
    about: &'static str,
    prog: String,
}

impl Args {
    pub fn new(about: &'static str) -> Self {
        Args { about, ..Default::default() }
    }

    /// Register an option with a default value.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: Some(default.to_string()), is_flag: false });
        self
    }

    /// Register a required option (no default).
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: false });
        self
    }

    /// Register a boolean flag (defaults to false).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: true });
        self
    }

    fn usage(&self) -> String {
        let mut s = format!("{}\n\nUSAGE: {} [OPTIONS]\n\nOPTIONS:\n", self.about, self.prog);
        for o in &self.opts {
            let d = match (&o.default, o.is_flag) {
                (_, true) => " (flag)".to_string(),
                (Some(d), _) if !d.is_empty() => format!(" [default: {d}]"),
                _ => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<24} {}{}\n", o.name, o.help, d));
        }
        s.push_str("  --help                     show this message\n");
        s
    }

    /// Parse process args. On `--help` prints usage and exits 0; on error
    /// prints usage and exits 2.
    pub fn parse(self) -> Parsed {
        let argv: Vec<String> = std::env::args().collect();
        self.parse_from(&argv)
    }

    pub fn parse_from(mut self, argv: &[String]) -> Parsed {
        self.prog = argv.first().cloned().unwrap_or_default();
        let mut i = 1;
        let die = |msg: &str, usage: &str| -> ! {
            eprintln!("error: {msg}\n\n{usage}");
            std::process::exit(2);
        };
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let Some(opt) = self.opts.iter().find(|o| o.name == key) else {
                    die(&format!("unknown option --{key}"), &self.usage());
                };
                let val = if opt.is_flag {
                    if inline_val.is_some() {
                        die(&format!("--{key} is a flag"), &self.usage());
                    }
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    if i >= argv.len() {
                        die(&format!("--{key} needs a value"), &self.usage());
                    }
                    argv[i].clone()
                };
                self.values.insert(key, val);
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        // Fill defaults, check required.
        for o in &self.opts {
            if !self.values.contains_key(o.name) {
                if o.is_flag {
                    self.values.insert(o.name.to_string(), "false".to_string());
                } else if let Some(d) = &o.default {
                    self.values.insert(o.name.to_string(), d.clone());
                } else {
                    die(&format!("--{} is required", o.name), &self.usage());
                }
            }
        }
        Parsed { values: self.values, positional: self.positional }
    }
}

#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option {name:?} was not registered"))
    }

    pub fn usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got {:?}", self.get(name)))
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got {:?}", self.get(name)))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number, got {:?}", self.get(name)))
    }

    pub fn f32(&self, name: &str) -> f32 {
        self.f64(name) as f32
    }

    pub fn bool(&self, name: &str) -> bool {
        self.get(name) == "true"
    }

    /// Comma-separated integer list, e.g. `--workers 1,4,8,16,32`.
    pub fn usize_list(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name} expects ints, got {s:?}"))
            })
            .collect()
    }

    pub fn f64_list(&self, name: &str) -> Vec<f64> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name} expects floats, got {s:?}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(parts.iter().map(|s| s.to_string()))
            .collect()
    }

    #[test]
    fn parses_key_value_and_defaults() {
        let p = Args::new("t")
            .opt("workers", "4", "n")
            .opt("gamma", "0.01", "g")
            .flag("verbose", "v")
            .parse_from(&argv(&["--workers", "8", "--verbose"]));
        assert_eq!(p.usize("workers"), 8);
        assert_eq!(p.f64("gamma"), 0.01);
        assert!(p.bool("verbose"));
    }

    #[test]
    fn parses_equals_form_and_lists() {
        let p = Args::new("t")
            .opt("workers", "1", "n")
            .opt("sweep", "1,2", "s")
            .parse_from(&argv(&["--workers=16", "--sweep=1,4,8"]));
        assert_eq!(p.usize("workers"), 16);
        assert_eq!(p.usize_list("sweep"), vec![1, 4, 8]);
    }

    #[test]
    fn positional_args_collected() {
        let p = Args::new("t").opt("x", "0", "x").parse_from(&argv(&["a", "--x", "1", "b"]));
        assert_eq!(p.positional, vec!["a", "b"]);
        assert_eq!(p.usize("x"), 1);
    }
}

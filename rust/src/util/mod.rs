//! In-tree substrates replacing crates unavailable offline: PRNG, JSON,
//! CLI parsing, leveled logging.  See DESIGN.md "Environment-driven
//! design decisions".

pub mod align;
pub mod cli;
pub mod json;
pub mod log;
pub mod rng;

pub use align::{AlignedBuf, CacheAligned};

/// Mean and sample standard deviation (used by reports and the bench
/// harness).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// p-th percentile (0..=100) of a sample, linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!((s - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }
}

//! Problem abstraction (S8): the general-form-consensus objective
//!
//! ```text
//! min  sum_i f_i({x_ij}) + h(z),   h(z) = lambda*||z||_1 + indicator(||z||_inf <= C)
//! ```
//!
//! with f_i a generalized linear loss over worker i's shard.  Instances:
//! sparse logistic regression (paper Eq. 22) and lasso (squared loss).
//! The per-margin math here is the single source of truth for the native
//! backend; the XLA backend's artifacts are generated from the matching
//! jnp formulas and cross-checked by `rust/tests/artifact_parity.rs`.

use crate::data::LossKind;

/// Regularizer + loss parameters for one experiment.
#[derive(Clone, Copy, Debug)]
pub struct Problem {
    pub kind: LossKind,
    /// l1 coefficient λ.
    pub lambda: f32,
    /// Box constraint ‖z‖∞ ≤ C.
    pub clip: f32,
}

impl Problem {
    pub fn new(kind: LossKind, lambda: f32, clip: f32) -> Self {
        Problem { kind, lambda, clip }
    }

    /// Per-sample loss φ(margin, y) and slope ∂φ/∂margin (unweighted).
    #[inline]
    pub fn loss_slope(&self, margin: f32, label: f32) -> (f32, f32) {
        match self.kind {
            LossKind::Logistic => {
                let t = -label * margin;
                // log(1+e^t) computed stably; sigmoid(t) likewise.
                let loss = if t > 0.0 { t + (-t).exp().ln_1p() } else { t.exp().ln_1p() };
                let sig = if t >= 0.0 {
                    1.0 / (1.0 + (-t).exp())
                } else {
                    let e = t.exp();
                    e / (1.0 + e)
                };
                (loss, -label * sig)
            }
            LossKind::Squared => {
                let r = margin - label;
                (0.5 * r * r, r)
            }
        }
    }

    /// Regularizer value h(z) = λ‖z‖₁ over the full model (box indicator
    /// contributes 0 for feasible z; iterates are feasible by
    /// construction of the prox).
    pub fn h(&self, z: &[f32]) -> f64 {
        self.lambda as f64 * z.iter().map(|v| v.abs() as f64).sum::<f64>()
    }

    /// Curvature bound max φ'' — feeds the block-Lipschitz estimates
    /// (Assumption 1) in `admm::penalty`.
    pub fn curvature_bound(&self) -> f32 {
        match self.kind {
            LossKind::Logistic => 0.25,
            LossKind::Squared => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logistic() -> Problem {
        Problem::new(LossKind::Logistic, 1e-4, 1e4)
    }

    #[test]
    fn logistic_loss_at_zero_margin() {
        let p = logistic();
        let (l, s) = p.loss_slope(0.0, 1.0);
        assert!((l - std::f32::consts::LN_2).abs() < 1e-6);
        assert!((s + 0.5).abs() < 1e-6);
    }

    #[test]
    fn logistic_loss_stable_at_extremes() {
        let p = logistic();
        let (l, s) = p.loss_slope(100.0, 1.0); // well classified
        assert!(l >= 0.0 && l < 1e-6);
        assert!(s.abs() < 1e-6);
        let (l2, s2) = p.loss_slope(-100.0, 1.0); // badly misclassified
        assert!((l2 - 100.0).abs() < 1e-3);
        assert!((s2 + 1.0).abs() < 1e-6);
        assert!(l.is_finite() && l2.is_finite());
    }

    #[test]
    fn squared_loss_and_slope() {
        let p = Problem::new(LossKind::Squared, 0.0, 1e4);
        let (l, s) = p.loss_slope(3.0, 1.0);
        assert_eq!(l, 2.0);
        assert_eq!(s, 2.0);
    }

    #[test]
    fn slope_is_derivative_numerically() {
        let p = logistic();
        for &(m, y) in &[(0.3f32, 1.0f32), (-1.2, -1.0), (2.0, -1.0)] {
            let eps = 1e-3;
            let (lp, _) = p.loss_slope(m + eps, y);
            let (lm, _) = p.loss_slope(m - eps, y);
            let (_, s) = p.loss_slope(m, y);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - s).abs() < 1e-3, "m={m} y={y}: fd {fd} vs slope {s}");
        }
    }

    #[test]
    fn h_is_l1() {
        let p = Problem::new(LossKind::Logistic, 2.0, 10.0);
        assert!((p.h(&[1.0, -2.0, 0.5]) - 7.0).abs() < 1e-9);
    }
}

//! Discrete-event cluster simulator (S10) — the paper's 36-core EC2
//! deployment, virtualized.
//!
//! Why this exists: this build machine has **one CPU core** (DESIGN.md
//! "environment-driven decisions"), so the paper's scaling study (Table
//! 1, Fig. 2b) cannot be reproduced with wall-clock threads.  The DES
//! runs Algorithm 1's *numerics for real* — every pull/compute/push/prox
//! happens with the same update code the threaded runtime uses, in a
//! virtual-time-consistent interleaving with genuine staleness — while
//! *durations* (gradient compute, network latency, server service time)
//! come from a cost model calibrated against measured executions on this
//! machine (see [`calibrate_native`] and `EXPERIMENTS.md`).
//!
//! Event chain per worker (matching Algorithm 1):
//!   PullDone(t) → snapshot z̃, pick block → ComputeDone(t + T_comp)
//!   → run Eqs. 11/12/9 on the *snapshot* → push w
//!   → Arrive(server, t + net) → FIFO queue, service T_srv → apply
//!   Eq. 13 → worker's next PullDone(t_compute_done + rtt).
//! Staleness is genuine: between a worker's pull and its push being
//! applied, other workers' pushes land on the same blocks.

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, VecDeque};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::admm::{objective_at_z, prox_l1_box, worker_update, NativeEngine, Objective};
use crate::config::{BlockSelection, Config, DrainKind, FailurePolicy, PlacementKind};
use crate::coordinator::{make_placement, FaultEvent, FaultPlan, ObjSample, Observer, Progress, Topology};
use crate::coordinator::{
    plan_rebalance, REBALANCE_HYSTERESIS, REBALANCE_MAX_MOVES, REBALANCE_MIN_DELTA,
};
use crate::data::{Dataset, WorkerShard};
use crate::problem::Problem;
use crate::util::rng::Rng;

/// Calibrated cost model (seconds, virtual).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Fixed per-iteration worker overhead (dispatch, packing).
    pub compute_fixed_s: f64,
    /// Per-data-row gradient cost (margins + block accumulate); used
    /// when `chunk_rows == 0` (linear model, native CSR backend).
    pub compute_per_row_s: f64,
    /// Server service time per push (Eq. 13 over one block).
    pub server_service_s: f64,
    /// Mean one-way network latency (exponential, truncated at 4×).
    pub net_mean_s: f64,
    /// If non-zero: chunk-granular compute (the XLA backend executes
    /// whole padded chunks of this many rows) —
    /// compute = fixed + per_chunk_s * ceil(rows / chunk_rows).
    pub chunk_rows: usize,
    pub per_chunk_s: f64,
    /// Relative per-iteration compute jitter j: each iteration's compute
    /// is scaled by U(1-j, 1+j) (mean 1). Models shared-tenancy variance
    /// on the paper's EC2 c4 instances; 0 = deterministic.
    pub compute_jitter: f64,
    /// Blocks `0..slow_head_blocks` cost `slow_head_factor ×` the base
    /// service time per push — per-block service skew (denser columns,
    /// heavier prox) for the service-time-aware rebalancing study
    /// (EXPERIMENTS.md E9).  0 = uniform service times.
    pub slow_head_blocks: usize,
    /// Service-time multiplier for the slow head (ignored when
    /// `slow_head_blocks == 0`).
    pub slow_head_factor: f64,
    /// Weight the dynamic re-placement plan by observed rate × per-block
    /// service-time EWMA (the threaded Rebalancer's cost model); false
    /// replays the legacy rate-only policy for ablations.
    pub cost_weighted_rebalance: bool,
}

impl CostModel {
    /// Per-iteration worker compute time for a shard of `rows` rows.
    pub fn compute_s(&self, rows: usize) -> f64 {
        if self.chunk_rows > 0 {
            self.compute_fixed_s
                + self.per_chunk_s * rows.div_ceil(self.chunk_rows).max(1) as f64
        } else {
            self.compute_fixed_s + self.compute_per_row_s * rows as f64
        }
    }

    /// Virtual service time for one push to block `j` (Eq. 13 over one
    /// block), including the slow-head skew.
    pub fn service_s(&self, j: usize) -> f64 {
        if j < self.slow_head_blocks {
            self.server_service_s * self.slow_head_factor
        } else {
            self.server_service_s
        }
    }
}

impl Default for CostModel {
    fn default() -> Self {
        // Placeholder flavor; experiments calibrate via
        // `calibrate_native` / `calibrate_xla`.
        CostModel {
            compute_fixed_s: 2e-4,
            compute_per_row_s: 5e-6,
            server_service_s: 3e-5,
            net_mean_s: 5e-4,
            chunk_rows: 0,
            per_chunk_s: 0.0,
            compute_jitter: 0.0,
            slow_head_blocks: 0,
            slow_head_factor: 1.0,
            cost_weighted_rebalance: true,
        }
    }
}

/// Measure the native per-row gradient cost and per-block prox cost on
/// this machine, for the cost model.  (One worker's real step, timed.)
pub fn calibrate_native(ds: &Dataset, shards: &[WorkerShard], problem: Problem) -> CostModel {
    let shard = &shards[0];
    let weight = 1.0 / ds.samples() as f32;
    let mut eng = NativeEngine::new(shard, problem, weight);
    let z = vec![0.0f32; shard.packed_dim()];
    let mut g = vec![0.0f32; shard.block_size];
    // Warm + measure gradient.
    eng.grad_block(&z, 0, &mut g);
    let reps = 10.max(200_000 / shard.samples().max(1));
    let t0 = Instant::now();
    for _ in 0..reps {
        eng.grad_block(&z, 0, &mut g);
    }
    let per_step = t0.elapsed().as_secs_f64() / reps as f64;
    let per_row = per_step / shard.samples().max(1) as f64;

    // Prox cost per block.
    let db = shard.block_size;
    let (zt, ws) = (vec![0.1f32; db], vec![0.2f32; db]);
    let mut out = vec![0.0f32; db];
    let t0 = Instant::now();
    for _ in 0..1000 {
        prox_l1_box(&zt, &ws, 0.01, 100.0, 1e-5, 1e4, &mut out);
    }
    let prox_s = t0.elapsed().as_secs_f64() / 1000.0;

    CostModel {
        compute_fixed_s: per_step * 0.05 + 1e-6,
        compute_per_row_s: per_row,
        // Service = prox + message handling overhead (~2x prox).
        server_service_s: prox_s * 2.0 + 1e-6,
        net_mean_s: 2e-4, // EC2-like intra-AZ latency, scaled down
        chunk_rows: 0,
        per_chunk_s: 0.0,
        compute_jitter: 0.0,
        ..CostModel::default()
    }
}

/// Calibrate the cost model against the PRODUCTION worker path: the AOT
/// XLA `worker_step` artifact executed over one dense chunk.  This is
/// what a deployed AsyBADMM worker actually runs per iteration, so the
/// Table 1 / Fig. 2(b) virtual timings are anchored to measured
/// executions of the real artifact on this machine.
pub fn calibrate_xla(
    manifest: &crate::runtime::Manifest,
    kind: crate::data::LossKind,
    db: usize,
    m_chunk: usize,
    d_pad: usize,
) -> Result<CostModel> {
    use crate::data::{gen_partitioned, BlockGeometry, SynthSpec};
    use crate::runtime::{ServerProxXla, WorkerXla, XlaEngine};
    // Reference shard exactly matching the artifact shape: m_chunk rows,
    // d_pad packed width (one chunk). The measured per-chunk time is the
    // production per-block-update cost at the reference shape.
    let blocks = d_pad / db;
    let spec = SynthSpec {
        kind,
        samples: m_chunk,
        geometry: BlockGeometry::new(blocks, db),
        nnz_per_row: 40.min(d_pad / 4).max(1),
        blocks_per_worker: blocks,
        shared_blocks: 1,
        seed: 1234,
        ..Default::default()
    };
    let (_, shards) = gen_partitioned(&spec, 1);
    let shard = &shards[0];
    let weight = 1.0 / m_chunk as f32;
    let engine = XlaEngine::new(manifest, kind.as_str(), m_chunk, d_pad, db)?;
    let mut wx = WorkerXla::new(engine, shard, weight)?;
    let z = vec![0.01f32; shard.packed_dim()];
    let y = vec![0.0f32; db];
    wx.step(&z, &y, 0, 4.0)?; // warm (compile caches, first dispatch)
    let reps = 5usize.max(20 / wx.n_chunks());
    let t0 = Instant::now();
    for _ in 0..reps {
        wx.step(&z, &y, 0, 4.0)?;
    }
    let per_iter = t0.elapsed().as_secs_f64() / reps as f64;
    let per_chunk = per_iter / wx.n_chunks() as f64;

    // Server service: the XLA prox artifact per push.
    let sp = ServerProxXla::load(manifest, db)?;
    let (zt, ws) = (vec![0.1f32; db], vec![0.2f32; db]);
    sp.prox(&zt, &ws, 0.01, 16.0, 1e-5, 1e4)?;
    let t0 = Instant::now();
    for _ in 0..50 {
        sp.prox(&zt, &ws, 0.01, 16.0, 1e-5, 1e4)?;
    }
    let prox_s = t0.elapsed().as_secs_f64() / 50.0;

    Ok(CostModel {
        compute_fixed_s: 5e-6,
        compute_per_row_s: per_chunk / m_chunk as f64,
        server_service_s: prox_s + 2e-6,
        net_mean_s: 2e-4, // EC2-like intra-AZ latency
        chunk_rows: m_chunk,
        per_chunk_s: per_chunk,
        compute_jitter: 0.0,
        ..CostModel::default()
    })
}

#[derive(Debug)]
enum Ev {
    /// Worker finished pulling z̃ — snapshot & start computing.
    PullDone { worker: usize },
    /// Worker finished its gradient + update for `slot`.
    ComputeDone { worker: usize, slot: usize },
    /// A push reaches its server's inbox.
    Arrive { server: usize, push: SimPush },
    /// A server thread finishes servicing `push` (popped from the
    /// queue when service started, so several can be in flight per
    /// shard under the elastic/steal pool).
    ServiceDone { server: usize, push: SimPush },
    /// Dynamic re-placement scan (placement=dynamic only): re-map hot
    /// blocks from the observed per-block service counts.
    Rebalance,
}

#[derive(Debug)]
struct SimPush {
    worker: usize,
    block: usize,
    w: Vec<f32>,
}

impl CostModel {
    /// Convert a chunk-granular model to a rows-linear one (per-row =
    /// per_chunk / chunk_rows).  Used for the paper-regime scaling
    /// studies: the paper's ps-lite workers stream CSR rows, so their
    /// per-iteration cost is rows-linear and width-independent; we keep
    /// the per-row *rate* measured on the real XLA artifact.
    pub fn linearized(mut self) -> CostModel {
        if self.chunk_rows > 0 {
            self.compute_per_row_s = self.per_chunk_s / self.chunk_rows as f64;
            self.chunk_rows = 0;
            self.per_chunk_s = 0.0;
        }
        self
    }
}

struct Scheduled {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // Min-heap via reversed compare; ties broken by seq for
        // determinism.
        other
            .t
            .partial_cmp(&self.t)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct SimWorker<'a> {
    shard: &'a WorkerShard,
    engine: NativeEngine<'a>,
    x: Vec<f32>,
    y: Vec<f32>,
    z_snapshot: Vec<f32>,
    epoch: usize,
    rng: Rng,
    compute_s: f64,
}

/// One shard's inbound queue.  Mirroring the threaded runtime's shared
/// `BlockTable`, the per-block numeric state lives in [`SimBlocks`]
/// (global), so a dynamically migrated block keeps its w̃ cache no
/// matter which station services it.
struct SimServer {
    queue: VecDeque<SimPush>,
    /// Pushes currently being serviced by some pool thread (≤ 1 in the
    /// classic one-thread-per-shard shape; up to the lane count —
    /// one per worker — under the elastic/steal pool).
    in_service: usize,
}

/// Per-block server state, dense over global block ids (the DES mirror
/// of the threaded runtime's `BlockTable`).
struct SimBlocks {
    w_tilde: Vec<Vec<Vec<f32>>>,
    w_sum: Vec<Vec<f32>>,
    denom: Vec<f32>,
    worker_slot: Vec<Vec<usize>>,
}

#[derive(Debug)]
pub struct SimReport {
    pub samples: Vec<ObjSample>,
    pub final_objective: Objective,
    pub virtual_time_s: f64,
    pub epochs: usize,
    /// Virtual time when min-epoch first reached k, for every k ≤ epochs.
    pub time_to_epoch: Vec<f64>,
    pub z_final: Vec<f32>,
    /// Total pushes served.
    pub pushes: usize,
    /// Max server backlog observed — queued plus in-service pushes
    /// (contention indicator).
    pub max_queue: usize,
    /// Blocks migrated between shards (`placement=dynamic` only).
    pub migrations: usize,
    /// Final block→server routing map (differs from the initial
    /// contiguous assignment only under `placement=dynamic`).
    pub placement_final: Vec<usize>,
    /// Injected faults and recovery transitions, in virtual-time order
    /// (the DES mirror of `TrainReport::faults`).
    pub faults: Vec<FaultEvent>,
}

/// Run Algorithm 1 under the DES with the given cost model.
///
/// Prefer `Session::builder(cfg).dataset(..).algo(Algo::Sim(cost)).run()`
/// for the unified `TrainReport` surface; this remains the raw entry.
pub fn run_sim(
    cfg: &Config,
    ds: &Dataset,
    shards: &[WorkerShard],
    cost: &CostModel,
) -> Result<SimReport> {
    run_sim_observed(cfg, ds, shards, cost, &mut [])
}

/// [`run_sim`] with [`Observer`] hooks: each watermark sample also
/// fires `on_sample` with a virtual-time [`Progress`] view, exactly
/// mirroring the threaded runtime's monitor (the final-state row is
/// appended to `samples` only).  This is what `Algo::Sim` calls.
pub fn run_sim_observed(
    cfg: &Config,
    ds: &Dataset,
    shards: &[WorkerShard],
    cost: &CostModel,
    observers: &mut [Box<dyn Observer + '_>],
) -> Result<SimReport> {
    cfg.validate()?;
    let problem = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
    let weight = 1.0 / ds.samples() as f32;
    // Same block→shard placement as the threaded runtime, so the DES's
    // per-server queue shapes (Table-1 contention) stay comparable with
    // `--set placement=…` runs.  (The drain policy is not modeled: a
    // DES server is a pure service station, and stealing only
    // re-assigns which thread pays the service time.)
    let placement = make_placement(cfg.placement);
    let topo = Topology::build_with(shards, cfg.n_blocks, cfg.n_servers, placement.as_ref());
    let db = cfg.block_size;
    let d = cfg.n_blocks * db;

    let mut z = vec![0.0f32; d];
    let mut workers: Vec<SimWorker> = shards
        .iter()
        .map(|s| SimWorker {
            shard: s,
            // f_i = local mean (see driver.rs / DESIGN.md).
            engine: NativeEngine::new(s, problem, 1.0 / s.samples().max(1) as f32),
            x: vec![0.0; s.packed_dim()],
            y: vec![0.0; s.packed_dim()],
            z_snapshot: vec![0.0; s.packed_dim()],
            epoch: 0,
            rng: Rng::new(cfg.seed ^ (s.worker_id as u64 * 0x9E37_79B9 + 1)),
            compute_s: cost.compute_s(s.samples()),
        })
        .collect();

    // Per-block numeric state, global (the DES mirror of the threaded
    // runtime's shared BlockTable): migration only changes which
    // station services a block, never where its w̃ cache lives.
    let mut blocks = {
        let mut w_tilde = Vec::with_capacity(cfg.n_blocks);
        let mut w_sum = Vec::with_capacity(cfg.n_blocks);
        let mut denom = Vec::with_capacity(cfg.n_blocks);
        let mut worker_slot = Vec::with_capacity(cfg.n_blocks);
        for j in 0..cfg.n_blocks {
            let degree = topo.workers_of_block[j].len();
            w_tilde.push(vec![vec![0.0f32; db]; degree]);
            w_sum.push(vec![0.0f32; db]);
            denom.push(cfg.gamma + cfg.rho * degree as f32);
            let mut slots = vec![usize::MAX; topo.n_workers];
            for (s, &w) in topo.workers_of_block[j].iter().enumerate() {
                slots[w] = s;
            }
            worker_slot.push(slots);
        }
        SimBlocks { w_tilde, w_sum, denom, worker_slot }
    };
    let mut servers: Vec<SimServer> =
        (0..cfg.n_servers).map(|_| SimServer { queue: VecDeque::new(), in_service: 0 }).collect();

    // Elastic pool + drain model: the classic shape (server_threads=0,
    // drain=owned) dedicates one thread per shard (at most one push in
    // service per station, exactly the pre-pool DES).  A pool
    // (`server_threads != n_servers` or `drain=steal`) shares
    // `k_threads` threads across all stations: idle threads pick up any
    // backlogged queue, and one shard can be serviced by several
    // threads at once — capped at its lane count (one SPSC lane per
    // worker), matching `coordinator/sched.rs`.
    let k_threads = if cfg.server_threads == 0 { cfg.n_servers } else { cfg.server_threads };
    let pool = k_threads != cfg.n_servers || matches!(cfg.drain, DrainKind::Steal);
    let mut idle = k_threads;
    let max_conc = if pool { cfg.n_workers.max(1) } else { 1 };

    // Dynamic re-placement state (placement=dynamic): the routing map
    // starts at the placement's initial (contiguous) assignment and is
    // re-packed from observed service counts at Rebalance events, with
    // the same noise floor / hysteresis / burst bound as the threaded
    // Rebalancer.
    let dynamic = cfg.placement == PlacementKind::Dynamic && cfg.n_servers > 1;
    let mut server_of_block = topo.server_of_block.clone();
    let mut served_per_block = vec![0usize; cfg.n_blocks];
    let mut last_counts = vec![0usize; cfg.n_blocks];
    // Per-block virtual service-time EWMA (ns, α = 1/8) — the DES mirror
    // of the threaded BlockTable's sampled wall-clock EWMA (0 = no
    // sample yet, exactly like `BlockTable::service_ewma_ns`).
    let mut svc_ewma = vec![0u64; cfg.n_blocks];
    let mut migrations = 0usize;
    let rebalance_s = cfg.rebalance_ms.max(1) as f64 * 1e-3;

    // Fault mirror (DESIGN.md §2.0.3): the same deterministic plan the
    // threaded runtime consults, replayed in virtual time.  Crash fires
    // after the epoch's push is in flight (matching the worker hook's
    // placement after the send), stall inflates one service time, and
    // transient send failures pay extra network hops before arrival.
    let plan = FaultPlan::parse(&cfg.faults)?;
    let faults_on = !plan.is_empty();
    // Degraded workers: chain stopped, epoch frozen, w̃ contributions
    // left in `blocks` (the survivors' consensus still includes them).
    let mut dead = vec![false; cfg.n_workers];
    // Restart pending: the replacement warm-starts at its next PullDone
    // — by then the crashed worker's only in-flight push has been
    // serviced, the DES analogue of `wait_tail_drained`.
    let mut restarting = vec![false; cfg.n_workers];
    let mut restarts = vec![0usize; cfg.n_workers];
    // Per-(worker, slot) sent-history — the DES ledger: a replacement
    // only warm-starts duals for slots the dead worker actually pushed
    // (a never-pushed slot's true local dual is y⁰ = 0).
    let mut pushed: Vec<Vec<bool>> =
        shards.iter().map(|s| vec![false; s.n_slots()]).collect();
    // Per-station applied-push counters for the stall trigger (the
    // mirror of `ServerShard::pushes`).
    let mut served = vec![0usize; cfg.n_servers];

    let mut heap: BinaryHeap<Scheduled> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut push_ev = |heap: &mut BinaryHeap<Scheduled>, t: f64, ev: Ev| {
        seq += 1;
        heap.push(Scheduled { t, seq, ev });
    };
    let mut net = {
        let mut rng = Rng::new(cfg.seed ^ 0xDEAD_BEEF);
        move |mean: f64| -> f64 {
            if mean <= 0.0 {
                0.0
            } else {
                rng.exponential(1.0 / mean).min(4.0 * mean)
            }
        }
    };

    for w in 0..cfg.n_workers {
        push_ev(&mut heap, 0.0, Ev::PullDone { worker: w });
    }
    if dynamic {
        push_ev(&mut heap, rebalance_s, Ev::Rebalance);
    }

    // Start servicing shard `s`'s backlog with whatever thread capacity
    // the model grants it (see the pool comment above).
    macro_rules! start_service {
        ($heap:expr, $t:expr, $s:expr) => {{
            let s = $s;
            while servers[s].in_service < max_conc
                && !servers[s].queue.is_empty()
                && (!pool || idle > 0)
            {
                let push = servers[s].queue.pop_front().unwrap();
                servers[s].in_service += 1;
                if pool {
                    idle -= 1;
                }
                let mut svc = cost.service_s(push.block);
                if faults_on {
                    // Injected straggler: one service pays the stall
                    // (the threaded hook sleeps in handle_push).  The
                    // plan records the ServerStalled event itself.
                    if let Some(ms) = plan.stall_ms(s, served[s]) {
                        svc += ms as f64 * 1e-3;
                    }
                }
                // Observe the block's service time (stalls included,
                // exactly as a wall-clock sample would see them).
                let dt = ((svc * 1e9) as u64).max(1);
                let prev = svc_ewma[push.block];
                svc_ewma[push.block] = if prev == 0 { dt } else { (prev * 7 + dt) / 8 };
                push_ev($heap, $t + svc, Ev::ServiceDone { server: s, push });
            }
        }};
    }

    let log_every = cfg.log_every.max(1);
    let mut samples: Vec<ObjSample> = Vec::new();
    let mut time_to_epoch = vec![0.0f64; cfg.epochs + 1];
    let mut recorded_min_epoch = 0usize;
    let mut next_sample = 0usize;
    let mut pushes = 0usize;
    let mut max_queue = 0usize;
    let mut now = 0.0f64;
    let mut g_scratch = vec![0.0f32; db];
    let (mut w_new, mut y_new, mut x_new) =
        (vec![0.0f32; db], vec![0.0f32; db], vec![0.0f32; db]);
    let mut z_out = vec![0.0f32; db];

    while let Some(Scheduled { t, ev, .. }) = heap.pop() {
        now = t;
        match ev {
            Ev::PullDone { worker } => {
                let wk = &mut workers[worker];
                if wk.epoch >= cfg.epochs || dead[worker] {
                    // Budget spent — or a degraded worker's last ack
                    // arriving after its retirement.  Chain ends here.
                    continue;
                }
                if faults_on && restarting[worker] {
                    // Replacement worker takes over: its predecessor's
                    // in-flight push was serviced before this ack, so
                    // the warm start reads settled server state — x
                    // re-pulled from z̃, duals approximated as
                    // y ≈ w̃ − ρ·z̃ for slots with push history (the
                    // threaded `approx_duals`), y⁰ = 0 elsewhere.
                    restarting[worker] = false;
                    restarts[worker] += 1;
                    let shard = wk.shard;
                    for (slot, &j) in shard.active_blocks.iter().enumerate() {
                        let (lo, hi) = (slot * db, (slot + 1) * db);
                        wk.x[lo..hi].copy_from_slice(&z[j * db..(j + 1) * db]);
                        if pushed[worker][slot] {
                            let ws = blocks.worker_slot[j][worker];
                            for k in 0..db {
                                wk.y[lo + k] =
                                    blocks.w_tilde[j][ws][k] - cfg.rho * z[j * db + k];
                            }
                        } else {
                            wk.y[lo..hi].fill(0.0);
                        }
                    }
                    plan.record(FaultEvent::WorkerRestarted {
                        worker,
                        epoch: wk.epoch,
                        attempt: restarts[worker],
                    });
                }
                // Snapshot z̃ (pull) — staleness begins here.
                for (slot, &j) in wk.shard.active_blocks.iter().enumerate() {
                    wk.z_snapshot[slot * db..(slot + 1) * db]
                        .copy_from_slice(&z[j * db..(j + 1) * db]);
                }
                let slot = match cfg.selection {
                    BlockSelection::UniformRandom => wk.rng.below(wk.shard.n_slots()),
                    BlockSelection::Cyclic => wk.epoch % wk.shard.n_slots(),
                };
                let mut dt = wk.compute_s;
                if cost.compute_jitter > 0.0 {
                    let j = cost.compute_jitter;
                    dt *= 1.0 - j + 2.0 * j * wk.rng.f64();
                }
                push_ev(&mut heap, t + dt, Ev::ComputeDone { worker, slot });
            }
            Ev::ComputeDone { worker, slot } => {
                let wk = &mut workers[worker];
                // Real numerics on the stale snapshot.
                let loss = wk.engine.grad_block(&wk.z_snapshot, slot, &mut g_scratch);
                let (lo, hi) = (slot * db, (slot + 1) * db);
                worker_update(
                    &g_scratch,
                    &wk.y[lo..hi],
                    &wk.z_snapshot[lo..hi],
                    cfg.rho,
                    &mut w_new,
                    &mut y_new,
                    &mut x_new,
                );
                wk.x[lo..hi].copy_from_slice(&x_new);
                wk.y[lo..hi].copy_from_slice(&y_new);
                let _ = loss;
                wk.epoch += 1;

                let j = wk.shard.active_blocks[slot];
                // Live routing map (re-packed at Rebalance events under
                // placement=dynamic; static otherwise).
                let server = server_of_block[j];
                let push = SimPush { worker, block: j, w: w_new.clone() };
                let mut delay = net(cost.net_mean_s);
                if faults_on {
                    // Transient send failures: each bounded retry pays
                    // one extra mean network hop in virtual time.  The
                    // push epoch is 0-based, matching the worker hook.
                    delay += plan.send_failures(worker, wk.epoch - 1) as f64 * cost.net_mean_s;
                }
                // Bounded in-flight (ps-lite / the threaded runtime's
                // sync_channel): the worker's next pull completes only
                // after its own push is serviced, so server backlog
                // throttles workers instead of growing unboundedly.
                push_ev(&mut heap, t + delay, Ev::Arrive { server, push });
                pushed[worker][slot] = true;

                // Injected crash — AFTER the push is in flight, the
                // exact placement of the threaded worker hook, so the
                // push stream has no hole for recovery to bridge.
                if faults_on && plan.should_crash(worker, wk.epoch) {
                    match cfg.failure {
                        FailurePolicy::Die => {
                            bail!(
                                "fault injection: worker {worker} crashed at epoch {} \
                                 (failure=die)",
                                wk.epoch
                            );
                        }
                        FailurePolicy::Degrade => {
                            // Retire the worker; its w̃ stays frozen in
                            // the table and its in-flight push still
                            // applies (the DES has no seq gaps to purge).
                            dead[worker] = true;
                            plan.record(FaultEvent::WorkerDegraded {
                                worker,
                                epoch: wk.epoch,
                                parked_dropped: 0,
                            });
                        }
                        FailurePolicy::Restart => {
                            plan.record(FaultEvent::WorkerCrashed {
                                worker,
                                epoch: wk.epoch,
                            });
                            // The replacement warm-starts at the next
                            // PullDone — after the tail is serviced.
                            restarting[worker] = true;
                        }
                    }
                }

                // Progress bookkeeping (min epoch across live workers;
                // a degraded worker's frozen epoch must not pin the
                // watermark forever).
                let min_epoch = workers
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| !dead[i])
                    .map(|(_, w)| w.epoch)
                    .min();
                let Some(min_epoch) = min_epoch else { continue };
                while recorded_min_epoch < min_epoch {
                    recorded_min_epoch += 1;
                    time_to_epoch[recorded_min_epoch] = t;
                }
                // Samples at `epoch == cfg.epochs` are the final-state
                // row appended after the loop, matching the threaded
                // monitor's no-sample-past-budget contract.
                if min_epoch >= next_sample && min_epoch < cfg.epochs {
                    let prog =
                        Progress::new_dense(min_epoch, t, &z, shards, &problem, weight);
                    samples.push(prog.sample());
                    for obs in observers.iter_mut() {
                        obs.on_sample(&prog);
                    }
                    next_sample = next_sample.max(min_epoch) + log_every;
                }
            }
            Ev::Arrive { server, push } => {
                servers[server].queue.push_back(push);
                max_queue =
                    max_queue.max(servers[server].queue.len() + servers[server].in_service);
                start_service!(&mut heap, t, server);
            }
            Ev::ServiceDone { server, push } => {
                // Eq. 13 on the global per-block state (shared-table
                // mirror: which station serviced it does not matter).
                let ws = blocks.worker_slot[push.block][push.worker];
                debug_assert_ne!(ws, usize::MAX, "foreign worker in sim");
                for ((acc, nv), ov) in blocks.w_sum[push.block]
                    .iter_mut()
                    .zip(&push.w)
                    .zip(blocks.w_tilde[push.block][ws].iter())
                {
                    *acc += nv - ov;
                }
                blocks.w_tilde[push.block][ws].copy_from_slice(&push.w);
                prox_l1_box(
                    &z[push.block * db..(push.block + 1) * db],
                    &blocks.w_sum[push.block],
                    cfg.gamma,
                    blocks.denom[push.block],
                    problem.lambda,
                    problem.clip,
                    &mut z_out,
                );
                z[push.block * db..(push.block + 1) * db].copy_from_slice(&z_out);
                pushes += 1;
                served[server] += 1;
                served_per_block[push.block] += 1;
                // Ack: worker pulls fresh z and starts its next
                // iteration one network hop later.
                push_ev(&mut heap, t + net(cost.net_mean_s), Ev::PullDone { worker: push.worker });

                // Release the thread, keep this station hot, then (pool
                // only) let the freed thread roam to other backlogs.
                servers[server].in_service -= 1;
                if pool {
                    idle += 1;
                }
                start_service!(&mut heap, t, server);
                if pool && idle > 0 {
                    for k in 1..cfg.n_servers {
                        start_service!(&mut heap, t, (server + k) % cfg.n_servers);
                    }
                }
            }
            Ev::Rebalance => {
                let delta: Vec<usize> = served_per_block
                    .iter()
                    .zip(&last_counts)
                    .map(|(c, l)| c.saturating_sub(*l))
                    .collect();
                let total: usize = delta.iter().sum();
                if total >= REBALANCE_MIN_DELTA {
                    last_counts.copy_from_slice(&served_per_block);
                    // Same planner as the threaded Rebalancer: weight =
                    // rate × service-time EWMA (cost), queued depth as
                    // the tiebreak — so the DES reacts identically to
                    // the same observation window.  The rate-only
                    // ablation keeps raw deltas as weights.
                    let weight: Vec<usize> = if cost.cost_weighted_rebalance {
                        delta
                            .iter()
                            .enumerate()
                            .map(|(j, &d)| d.saturating_mul(svc_ewma[j].max(1) as usize))
                            .collect()
                    } else {
                        delta.clone()
                    };
                    let mut qdepth = vec![0usize; cfg.n_blocks];
                    for srv in &servers {
                        for p in &srv.queue {
                            qdepth[p.block] += 1;
                        }
                    }
                    for (j, s) in plan_rebalance(
                        &server_of_block,
                        &weight,
                        &qdepth,
                        cfg.n_servers,
                        REBALANCE_HYSTERESIS,
                        REBALANCE_MAX_MOVES,
                    ) {
                        server_of_block[j] = s;
                        migrations += 1;
                    }
                }
                // Keep scanning while any LIVE worker still has epochs
                // to run; once all budgets are spent (or every worker
                // degraded) the event chain ends and the heap drains
                // naturally — a dead worker's frozen epoch must not
                // reschedule this forever.
                if workers.iter().enumerate().any(|(i, w)| !dead[i] && w.epoch < cfg.epochs) {
                    push_ev(&mut heap, t + rebalance_s, Ev::Rebalance);
                }
            }
        }
    }

    let final_objective = objective_at_z(shards, &problem, weight, &z);
    samples.push(ObjSample {
        time_s: now,
        epoch: cfg.epochs,
        objective: final_objective.total(),
        data_loss: final_objective.data_loss,
        consensus_max: 0.0,
    });
    Ok(SimReport {
        samples,
        final_objective,
        virtual_time_s: now,
        epochs: cfg.epochs,
        time_to_epoch,
        z_final: z,
        pushes,
        max_queue,
        migrations,
        placement_final: server_of_block,
        faults: plan.take_events(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_partitioned;

    fn tiny_cost() -> CostModel {
        CostModel {
            compute_fixed_s: 1e-4,
            compute_per_row_s: 1e-5,
            server_service_s: 1e-5,
            net_mean_s: 1e-4,
            chunk_rows: 0,
            per_chunk_s: 0.0,
            compute_jitter: 0.0,
            ..CostModel::default()
        }
    }

    #[test]
    fn sim_converges_and_tracks_time() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 200; // one block per epoch => ~50 full passes
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let r = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        assert!(r.final_objective.total() < std::f64::consts::LN_2 * 0.9);
        assert!(r.virtual_time_s > 0.0);
        // time_to_epoch is monotone
        for k in 1..=50 {
            assert!(r.time_to_epoch[k] >= r.time_to_epoch[k - 1]);
        }
        assert!(r.pushes >= 50 * cfg.n_workers);
    }

    #[test]
    fn sim_is_deterministic() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 20;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let a = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        let b = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        assert_eq!(a.virtual_time_s, b.virtual_time_s);
        assert_eq!(a.z_final, b.z_final);
        assert_eq!(a.pushes, b.pushes);
    }

    #[test]
    fn sim_scales_near_linearly_with_workers() {
        // Strong scaling: same total data, k iterations; per-iteration
        // compute ∝ m/p, so T_k(p) ≈ T_k(1)/p until the server saturates.
        let k = 20;
        let mut times = Vec::new();
        for p in [1usize, 4] {
            let mut cfg = Config::tiny_test();
            cfg.epochs = k;
            cfg.n_workers = p;
            cfg.samples = 96;
            let (ds, shards) = gen_partitioned(&cfg.synth_spec(), p);
            let r = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
            times.push(r.time_to_epoch[k]);
        }
        let speedup = times[0] / times[1];
        assert!(speedup > 2.0, "4-worker speedup only {speedup:.2}");
        assert!(speedup <= 4.5, "superlinear? {speedup:.2}");
    }

    #[test]
    fn sim_observers_mirror_the_sample_stream() {
        struct Tap<'a> {
            rows: &'a mut Vec<(usize, f64)>,
        }
        impl Observer for Tap<'_> {
            fn on_sample(&mut self, p: &Progress<'_>) {
                self.rows.push((p.epoch, p.objective().total()));
            }
        }
        let mut cfg = Config::tiny_test();
        cfg.epochs = 40;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let mut rows = Vec::new();
        let mut obs: Vec<Box<dyn Observer + '_>> = vec![Box::new(Tap { rows: &mut rows })];
        let r = run_sim_observed(&cfg, &ds, &shards, &tiny_cost(), &mut obs).unwrap();
        drop(obs);
        // The observer saw exactly the watermark samples (the final-state
        // row is appended to `samples` only), with identical objectives.
        assert_eq!(rows.len(), r.samples.len() - 1);
        for ((e, o), s) in rows.iter().zip(&r.samples) {
            assert_eq!(*e, s.epoch);
            assert!((o - s.objective).abs() < 1e-12);
        }
        assert!(r.samples.iter().all(|s| s.epoch <= cfg.epochs));
        assert_eq!(
            r.samples.iter().filter(|s| s.epoch == cfg.epochs).count(),
            1,
            "final sample duplicated"
        );
    }

    #[test]
    fn sim_dynamic_placement_migrates_and_converges() {
        use crate::config::PlacementKind;
        let mut cfg = Config::tiny_test();
        cfg.epochs = 300;
        cfg.placement = PlacementKind::Dynamic;
        cfg.rebalance_ms = 1;
        // Unambiguous Zipf head: 3 of 4 active blocks shared by every
        // worker, all parked on shard 0 by the contiguous start.
        cfg.shared_blocks = 3;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let r = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        // The Zipf head starts contiguous on shard 0; the observed-rate
        // re-pack must move something.
        assert!(r.migrations > 0, "dynamic DES never migrated");
        assert!(r.final_objective.total() < std::f64::consts::LN_2 * 0.95);
        assert_eq!(r.pushes, cfg.epochs * cfg.n_workers);
        // Determinism holds with migration in the loop too.
        let r2 = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        assert_eq!(r.z_final, r2.z_final);
        assert_eq!(r.migrations, r2.migrations);
    }

    #[test]
    fn sim_cost_model_isolates_slow_block_where_rate_only_pairs_it() {
        use crate::config::{BlockSelection, PlacementKind};
        // 4 blocks on 2 servers (contiguous start [0,0,1,1]), every
        // worker cycling over every block ⇒ per-block push rates are
        // (near-)equal, so a rate-only planner sees balance and always
        // packs the blocks 2+2.  Block 0's service is 9× the rest:
        // the cost model (rate × service EWMA) sees weights ≈ [9,1,1,1]
        // and LPT isolates the slow block on its own shard — the move
        // rate-only can never justify.
        let mk = |weighted: bool| {
            let mut cfg = Config::tiny_test();
            cfg.epochs = 300;
            cfg.n_workers = 4;
            cfg.n_blocks = 4;
            cfg.blocks_per_worker = 4;
            cfg.shared_blocks = 4;
            cfg.placement = PlacementKind::Dynamic;
            cfg.selection = BlockSelection::Cyclic;
            cfg.rebalance_ms = 100;
            let cost = CostModel {
                // Compute-dominated period keeps the workers in a
                // deterministic lockstep rotation (queues drain between
                // rounds), so per-block rate deltas stay near-equal.
                compute_fixed_s: 1e-3,
                compute_per_row_s: 0.0,
                server_service_s: 1e-5,
                net_mean_s: 0.0,
                slow_head_blocks: 1,
                slow_head_factor: 9.0,
                cost_weighted_rebalance: weighted,
                ..CostModel::default()
            };
            (cfg, cost)
        };
        let (cfg, cost) = mk(true);
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let r_cost = run_sim(&cfg, &ds, &shards, &cost).unwrap();
        let (cfg_rate, rate_only) = mk(false);
        let r_rate = run_sim(&cfg_rate, &ds, &shards, &rate_only).unwrap();

        // Blocks co-resident with the slow block 0 (incl. itself).
        let partners =
            |map: &[usize]| map.iter().filter(|&&s| s == map[0]).count();
        assert!(r_cost.migrations > 0, "cost model never migrated");
        assert_eq!(
            partners(&r_cost.placement_final),
            1,
            "slow block not isolated: {:?}",
            r_cost.placement_final
        );
        assert_eq!(
            partners(&r_rate.placement_final),
            2,
            "rate-only planner should keep the slow block paired: {:?}",
            r_rate.placement_final
        );
        // Both arms run the full budget and converge.
        assert_eq!(r_cost.pushes, cfg.epochs * cfg.n_workers);
        assert_eq!(r_rate.pushes, r_cost.pushes);
        assert!(r_cost.final_objective.total() < std::f64::consts::LN_2 * 0.95);
        // Determinism with the cost model in the loop.
        let r2 = run_sim(&cfg, &ds, &shards, &cost).unwrap();
        assert_eq!(r_cost.z_final, r2.z_final);
        assert_eq!(r_cost.placement_final, r2.placement_final);
    }

    #[test]
    fn sim_steal_pool_drains_a_hot_shard_faster() {
        // ROADMAP item: predict the multi-core `steal_vs_owned_drain`
        // gate shape.  Every worker's footprint is the shared head
        // (blocks 0..4 of 8), which contiguous placement parks on shard
        // 0 — under `owned` one station serializes all service; under
        // `steal` idle threads service shard 0's other lanes.
        use crate::config::DrainKind;
        let mk = |drain: DrainKind| {
            let mut cfg = Config::tiny_test();
            cfg.epochs = 40;
            cfg.n_workers = 4;
            cfg.blocks_per_worker = 4;
            cfg.shared_blocks = 4;
            cfg.drain = drain;
            cfg
        };
        // Service-dominated regime: the hot shard is the bottleneck.
        let cost = CostModel {
            compute_fixed_s: 1e-6,
            compute_per_row_s: 0.0,
            server_service_s: 1e-3,
            net_mean_s: 0.0,
            ..CostModel::default()
        };
        let cfg_owned = mk(DrainKind::Owned);
        let (ds, shards) = gen_partitioned(&cfg_owned.synth_spec(), cfg_owned.n_workers);
        let owned = run_sim(&cfg_owned, &ds, &shards, &cost).unwrap();
        let steal = run_sim(&mk(DrainKind::Steal), &ds, &shards, &cost).unwrap();
        assert_eq!(owned.pushes, steal.pushes);
        let speedup = owned.virtual_time_s / steal.virtual_time_s;
        assert!(
            speedup > 1.3,
            "steal pool did not relieve the hot shard: {speedup:.2}x \
             (owned {:.4}s vs steal {:.4}s)",
            owned.virtual_time_s,
            steal.virtual_time_s
        );
    }

    #[test]
    fn sim_elastic_thread_scarcity_slows_service() {
        // server_threads=1 over 2 shards halves the pool's service
        // capacity in a service-dominated regime.
        let cost = CostModel {
            compute_fixed_s: 1e-6,
            compute_per_row_s: 0.0,
            server_service_s: 1e-3,
            net_mean_s: 0.0,
            ..CostModel::default()
        };
        let mk = |threads: usize| {
            let mut cfg = Config::tiny_test();
            cfg.epochs = 40;
            cfg.n_workers = 4;
            // Every worker touches every block: the push load splits
            // 50/50 across the two shards deterministically, so the
            // classic 2-thread shape genuinely runs 2x the service
            // capacity of the 1-thread pool.
            cfg.blocks_per_worker = 8;
            cfg.shared_blocks = 8;
            cfg.server_threads = threads;
            cfg
        };
        let cfg2 = mk(2);
        let (ds, shards) = gen_partitioned(&cfg2.synth_spec(), cfg2.n_workers);
        let full = run_sim(&cfg2, &ds, &shards, &cost).unwrap();
        let scarce = run_sim(&mk(1), &ds, &shards, &cost).unwrap();
        assert_eq!(full.pushes, scarce.pushes);
        assert!(
            scarce.virtual_time_s > full.virtual_time_s * 1.1,
            "1-thread pool not slower: {:.4}s vs {:.4}s",
            scarce.virtual_time_s,
            full.virtual_time_s
        );
    }

    #[test]
    fn sim_restart_matches_the_fault_free_run_shape() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 200;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let ff = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        cfg.faults = "crash:w1@30".into();
        cfg.failure = FailurePolicy::Restart;
        let r = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        // No pushes lost: the replacement resumes the epoch budget where
        // the crash left it, so totals equal the fault-free run exactly.
        assert_eq!(r.pushes, ff.pushes);
        assert_eq!(r.pushes, cfg.epochs * cfg.n_workers);
        // Crash then restart, in that order, for the right worker.
        assert_eq!(
            r.faults,
            vec![
                FaultEvent::WorkerCrashed { worker: 1, epoch: 30 },
                FaultEvent::WorkerRestarted { worker: 1, epoch: 30, attempt: 1 },
            ]
        );
        // Survivor-objective neighborhood: the warm-started duals keep
        // the run convergent and near the fault-free objective.
        let (a, b) = (r.final_objective.total(), ff.final_objective.total());
        assert!(a < std::f64::consts::LN_2 * 0.95, "restarted run did not converge: {a}");
        assert!((a - b).abs() < 0.1, "restart drifted: {a} vs fault-free {b}");
        // Determinism holds with churn in the loop.
        let r2 = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        assert_eq!(r.z_final, r2.z_final);
        assert_eq!(r.faults, r2.faults);
        assert_eq!(r.virtual_time_s, r2.virtual_time_s);
    }

    #[test]
    fn sim_degrade_completes_on_survivors() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 40;
        cfg.faults = "crash:w0@5".into();
        cfg.failure = FailurePolicy::Degrade;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let r = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        // The victim pushed once per completed epoch (its in-flight
        // crash-epoch push still applies); survivors run the full budget.
        assert_eq!(r.pushes, (cfg.n_workers - 1) * cfg.epochs + 5);
        assert_eq!(
            r.faults,
            vec![FaultEvent::WorkerDegraded { worker: 0, epoch: 5, parked_dropped: 0 }]
        );
        assert_eq!(r.epochs, cfg.epochs);
        assert!(r.virtual_time_s > 0.0);
    }

    #[test]
    fn sim_die_policy_propagates_the_crash() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 20;
        cfg.faults = "crash:w2@3".into(); // failure=die is the default
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let err = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("worker 2 crashed at epoch 3"), "{msg}");
    }

    #[test]
    fn sim_stall_shows_up_in_virtual_time_and_the_log() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 20;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let ff = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        cfg.faults = "stall:s0@5+50ms".into();
        let r = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        assert_eq!(r.pushes, ff.pushes, "a stall must delay, never drop");
        assert!(
            r.virtual_time_s >= ff.virtual_time_s + 0.045,
            "50ms stall invisible in virtual time: {} vs {}",
            r.virtual_time_s,
            ff.virtual_time_s
        );
        assert!(r
            .faults
            .contains(&FaultEvent::ServerStalled { server: 0, after_pushes: 5, ms: 50 }));
    }

    #[test]
    fn sim_sendfail_delays_arrival_deterministically() {
        let mut cfg = Config::tiny_test();
        cfg.epochs = 20;
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let ff = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        cfg.faults = "sendfail:w0@2x100".into();
        let r = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        assert_eq!(r.pushes, ff.pushes, "transient send failures must not drop pushes");
        // 100 retries × net_mean_s (1e-4) ≈ 10ms of extra latency on one
        // push — visible, bounded, deterministic.
        assert!(r.virtual_time_s > ff.virtual_time_s);
        let r2 = run_sim(&cfg, &ds, &shards, &tiny_cost()).unwrap();
        assert_eq!(r.virtual_time_s, r2.virtual_time_s);
    }

    #[test]
    fn calibration_produces_positive_costs() {
        let cfg = Config::tiny_test();
        let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        let p = Problem::new(cfg.loss, cfg.lambda, cfg.clip);
        let c = calibrate_native(&ds, &shards, p);
        assert!(c.compute_per_row_s > 0.0);
        assert!(c.server_service_s > 0.0);
    }
}

//! # AsyBADMM — block-wise asynchronous distributed ADMM
//!
//! Production-quality reproduction of *"A Block-wise, Asynchronous and
//! Distributed ADMM Algorithm for General Form Consensus Optimization"*
//! (Zhu, Niu, Li, 2018) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordination contribution: a parameter-
//!   server runtime with per-block consensus state, lock-free block-wise
//!   asynchronous updates, bounded-delay tracking, plus baselines and a
//!   discrete-event cluster simulator for the paper's scaling study.
//! * **L2 (`python/compile/model.py`)** — worker/server compute graphs in
//!   JAX, AOT-lowered once to HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the fused
//!   margin + block-gradient hot-spot and the proximal update.
//!
//! ## Training API
//!
//! Every execution path — the threaded async runtime, the three
//! baselines, and the discrete-event simulator — runs through one
//! [`coordinator::Session`] builder and returns one
//! [`coordinator::TrainReport`]:
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use asybadmm::config::Config;
//! use asybadmm::coordinator::Session;
//! use asybadmm::data::gen_partitioned;
//!
//! let cfg = Config::small();
//! let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
//! let report = Session::builder(&cfg).dataset(&ds, &shards).run()?;
//! println!("objective {:.6}", report.final_objective.total());
//! # Ok(()) }
//! ```
//!
//! Three optional builder knobs:
//! * `.transport(..)` — the worker→server push queueing discipline
//!   ([`coordinator::Transport`]): the bounded-mpsc original, the
//!   lock-free per-worker SPSC ring, or loopback TCP sockets with
//!   credit-window backpressure, with up to `batch` w-blocks coalesced
//!   per slot (`--set transport=mpsc|ring|tcp batch=N` on the CLI).
//! * `.observer(..)` — run telemetry hooks ([`coordinator::Observer`]);
//!   objective sampling is itself the built-in observer.
//! * `.algo(..)` — [`coordinator::Algo`]: `AsyncAdmm` (default),
//!   `SyncAdmm`, `LockedAdmm`, `HogwildSgd`, or `Sim` (virtual-time DES
//!   scaling study; extras in `TrainReport::sim`).
//!
//! Server-side policy knobs ride the config instead of the builder:
//! `--set placement=contiguous|roundrobin|hash|degree|dynamic` picks
//! the block→shard map ([`coordinator::Placement`]; `dynamic` starts
//! contiguous and migrates hot blocks at runtime from observed push
//! rates — [`coordinator::Rebalancer`], cadence `rebalance_ms`),
//! `--set drain=owned|steal` the server-thread queue draining (work
//! stealing; `coordinator/sched.rs`), and `--set server_threads=N`
//! decouples the server thread count from the shard count (an elastic
//! pool servicing all shards' lanes; 0 = one thread per shard).
//! `--set kernel=scalar|unrolled|simd|auto` (default `auto`) picks the
//! compute-kernel family ([`sparse::Kernels`]) used by both the worker
//! engine and the server apply path: `scalar` reference loops, the
//! 4-wide portable `unrolled` paths, or AVX2 `simd` (runtime-detected
//! via `is_x86_feature_detected!`; `auto` resolves to `simd` when AVX2
//! is present, else `unrolled`, and `simd` on a non-AVX2 host degrades
//! to `unrolled`). The prox and w̃-sum SIMD kernels are bit-identical
//! to scalar (no FMA), so the knob changes speed, never results. The
//! `dynamic` rebalancer weighs blocks by observed push rate × a
//! per-block EWMA of sampled service time (queue depth breaks ties),
//! so rarely-pushed-but-expensive blocks migrate too; with uniform
//! service times it reduces exactly to rate-based packing.
//!
//! Survivability knobs (`coordinator/fault.rs`, DESIGN.md §2.0.3):
//! `--set faults=SPEC` arms a deterministic, seeded
//! [`coordinator::FaultPlan`] (`crash:w1@5`, `stall:s0@100+25ms`,
//! `sendfail:w2@4x3`, `;`-separated) and `--set
//! failure=die|degrade|restart` picks what a worker crash does: `die`
//! propagates it, `degrade` completes on the survivors, `restart`
//! spawns a warm replacement (ledger-seeded `block_seq`, tail drain,
//! dual warm-start) with exact per-(worker, block) FIFO across the
//! window. `--set checkpoint_every=EPOCHS checkpoint_path=FILE` writes
//! periodic v2 checkpoints (z + duals + placement) the monitor thread
//! snapshots off the hot path, resumable via
//! `Session::builder(..).resume_from(&ck)`; `--set stall_warn_ms=MS`
//! arms a watchdog that reports a [`coordinator::FaultEvent::Stalled`]
//! to observers when no worker makes progress. Injected and observed
//! faults land in `TrainReport::faults`.
//!
//! ## Networked runtime (`coordinator/net/`, DESIGN.md §2.0.5)
//!
//! The same runtime also runs **multi-process**, std-only (no new
//! dependencies): `asybadmm serve --listen HOST:PORT` starts the
//! coordinator (server shards, [`coordinator::BlockTable`], rebalancer)
//! and `asybadmm work --connect HOST:PORT --rank R/N` runs the worker
//! ranks `w where w mod N == R` against it.  Worker processes join over
//! a length-prefixed little-endian wire format (`net/wire.rs`), receive
//! the full config + block-owner map in the `Welcome` handshake, push
//! through [`coordinator::TcpTransport`] lanes with **exact**
//! credit-window backpressure, mirror consensus state via a versioned
//! pull stream, and learn `placement=dynamic` migrations through
//! `OwnerUpdate` republishes.  `--set stats_addr=HOST:PORT` (any run,
//! in-process or serve mode) serves live JSON counters over hand-rolled
//! HTTP/1.1: `GET /stats` (per-shard load, applied-push counters,
//! placement map, migration ledger, fault events) and `GET /healthz`.
//!
//! See `DESIGN.md` (repo root) for the system inventory, the hot-path
//! mechanisms (seqlock block store, push-buffer pool, block-slice CSR
//! index, SPSC ring transport) and the environment-driven design
//! decisions, and `EXPERIMENTS.md` (repo root) for the experiment index
//! and paper-vs-measured results, tracked over time via
//! `BENCH_hotpath.json`.

pub mod admm;
pub mod baselines;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod problem;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod testutil;
pub mod util;

//! # AsyBADMM — block-wise asynchronous distributed ADMM
//!
//! Production-quality reproduction of *"A Block-wise, Asynchronous and
//! Distributed ADMM Algorithm for General Form Consensus Optimization"*
//! (Zhu, Niu, Li, 2018) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordination contribution: a parameter-
//!   server runtime with per-block consensus state, lock-free block-wise
//!   asynchronous updates, bounded-delay tracking, plus baselines and a
//!   discrete-event cluster simulator for the paper's scaling study.
//! * **L2 (`python/compile/model.py`)** — worker/server compute graphs in
//!   JAX, AOT-lowered once to HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the fused
//!   margin + block-gradient hot-spot and the proximal update.
//!
//! See `DESIGN.md` (repo root) for the system inventory, the hot-path
//! mechanisms (seqlock block store, push-buffer pool, block-slice CSR
//! index) and the environment-driven design decisions, and
//! `EXPERIMENTS.md` (repo root) for the experiment index and
//! paper-vs-measured results, tracked over time via `BENCH_hotpath.json`.

pub mod admm;
pub mod baselines;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod problem;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod testutil;
pub mod util;

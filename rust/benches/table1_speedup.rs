//! E3 bench: regenerate paper Table 1 (time to k iterations × worker
//! count + speedup) via the calibrated DES.  `cargo bench` runs the
//! quick profile; `examples/speedup_table1` is the full reproduction
//! recorded in EXPERIMENTS.md.

use asybadmm::config::Config;
use asybadmm::coordinator::{Algo, Session};
use asybadmm::data::gen_virtual_partitioned;
use asybadmm::report::SpeedupTable;
use asybadmm::sim::CostModel;

fn main() {
    if asybadmm::bench::maybe_list_gates() {
        return;
    }
    let quick = std::env::var("BENCH_QUICK").as_deref() == Ok("1");
    let ks = vec![20usize, 50, 100];
    let mut base = Config::default();
    base.epochs = 100;
    base.log_every = 10_000;
    if quick {
        base.samples = 1024;
    }

    println!("== Table 1: time-to-k iterations (virtual, calibrated DES) ==");
    let t0 = std::time::Instant::now();
    let mut rows = Vec::new();
    // Compute-dominated cost model (the paper's regime) so the gate is
    // calibration-independent; examples/speedup_table1 is the measured
    // reproduction.
    let cost = CostModel {
        compute_fixed_s: 1e-5,
        compute_per_row_s: 2e-5,
        server_service_s: 2e-5,
        net_mean_s: 2e-4,
        compute_jitter: 0.1,
        ..CostModel::default()
    };
    for p in [1usize, 4, 8, 16, 32] {
        let mut cfg = base.clone();
        cfg.n_workers = p;
        let (ds, shards) = gen_virtual_partitioned(&cfg.synth_spec(), 32, p);
        let r = Session::builder(&cfg)
            .dataset(&ds, &shards)
            .algo(Algo::Sim(cost))
            .run()
            .unwrap();
        let sx = r.sim.as_ref().expect("Algo::Sim reports sim extras");
        rows.push((p, ks.iter().map(|&k| sx.time_to_epoch[k]).collect::<Vec<_>>()));
    }
    let table = SpeedupTable { ks, rows };
    println!("{}", table.to_markdown());
    println!("paper speedups: 1.0 / 3.87 / 7.92 / 16.31 / 29.83");
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());

    // Sanity gates so `cargo bench` fails loudly if the shape regresses.
    let sp = table.speedups();
    let s32 = sp.iter().find(|(p, _)| *p == 32).map(|(_, s)| *s).unwrap_or(0.0);
    assert!(s32 > 8.0, "32-worker speedup collapsed: {s32:.2}");
    let s4 = sp.iter().find(|(p, _)| *p == 4).map(|(_, s)| *s).unwrap_or(0.0);
    assert!(s4 > 2.0, "4-worker speedup collapsed: {s4:.2}");
}

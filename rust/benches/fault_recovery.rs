//! Fault-model cost gates (EXPERIMENTS.md E8):
//!
//!  1. **Hook overhead**: a session with an ARMED but never-firing
//!     `FaultPlan` vs the empty plan.  Every hook is gated on one
//!     pre-computed `is_empty` branch, so `fault_hooks_overhead` must
//!     stay ≈ 1 — survivability may not tax the fault-free hot path.
//!  2. **Recovery cost**: `failure=restart` with a mid-run worker crash
//!     vs the fault-free run, at identical push totals.
//!     `recovery_vs_faultfree_epochs` is the wall-clock ratio of the
//!     recovered run over the fault-free run for the same epoch budget
//!     (tail-drain wait + warm-start re-read included).
//!
//!     cargo bench --bench fault_recovery [-- --json]
//!     BENCH_QUICK=1 cargo bench --bench fault_recovery -- --json

use std::time::Instant;

use asybadmm::bench::{emit_hotpath_json, harness_from_env, json_requested, maybe_list_gates, BenchResult};
use asybadmm::config::{Config, FailurePolicy};
use asybadmm::coordinator::Session;
use asybadmm::data::{gen_partitioned, Dataset, WorkerShard};

/// Best-of-N wall time for a full threaded session (min is robust to
/// scheduler noise on the 1-core CI host); asserts exact accounting.
fn timed(cfg: &Config, ds: &Dataset, shards: &[WorkerShard], reps: usize) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = Session::builder(cfg).dataset(ds, shards).run().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        // Fault-free, armed-but-inert, and restart-recovered runs must
        // all land the exact same push totals.
        assert_eq!(r.total_pushes(), cfg.epochs * cfg.n_workers, "pushes lost");
        best = best.min(dt);
    }
    best
}

fn record(h: &mut asybadmm::bench::Harness, name: &str, per_op_s: f64) {
    h.results.push(BenchResult {
        name: name.to_string(),
        samples: vec![per_op_s],
        mean_s: per_op_s,
        std_s: 0.0,
        p50_s: per_op_s,
        p95_s: per_op_s,
    });
}

fn main() {
    if maybe_list_gates() {
        return;
    }
    let quick = std::env::var("BENCH_QUICK").as_deref() == Ok("1");
    let mut h = harness_from_env();
    println!("== fault hooks + crash recovery ==");

    let mut cfg = Config::tiny_test();
    cfg.epochs = if quick { 300 } else { 1500 };
    let reps = if quick { 3 } else { 5 };
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);

    // Warm (thread spawn, page faults).
    let mut warm = cfg.clone();
    warm.epochs = 50;
    timed(&warm, &ds, &shards, 1);

    // 1. Empty plan vs armed-but-never-firing plan.
    let empty_s = timed(&cfg, &ds, &shards, reps);
    cfg.faults = format!("crash:w0@{}", usize::MAX); // armed, never fires
    let armed_s = timed(&cfg, &ds, &shards, reps);
    let overhead = armed_s / empty_s.max(1e-12);
    record(&mut h, "session, empty fault plan", empty_s);
    record(&mut h, "session, armed inert fault plan", armed_s);
    println!(
        "\nfault hooks ({} epochs x {} workers, best of {reps}):\n\
         \x20 empty plan {empty_s:.4}s | armed {armed_s:.4}s\n\
         \x20 -> fault_hooks_overhead = {overhead:.3}x  (gate: ~1, noise aside)",
        cfg.epochs, cfg.n_workers
    );

    // 2. Restart recovery vs fault-free, same budget and push totals.
    cfg.faults = format!("crash:w1@{}", cfg.epochs / 4);
    cfg.failure = FailurePolicy::Restart;
    let recovered_s = timed(&cfg, &ds, &shards, reps);
    let recovery = recovered_s / empty_s.max(1e-12);
    record(&mut h, "session, mid-run crash + restart", recovered_s);
    println!(
        "\ncrash at epoch {} + warm restart:\n\
         \x20 fault-free {empty_s:.4}s | recovered {recovered_s:.4}s\n\
         \x20 -> recovery_vs_faultfree_epochs = {recovery:.3}x \
         (tail drain + dual warm-start included)",
        cfg.epochs / 4
    );

    println!("\n{}", h.csv());

    if json_requested() {
        emit_hotpath_json(
            "fault_recovery",
            &h,
            &[
                ("fault_hooks_overhead", overhead),
                ("recovery_vs_faultfree_epochs", recovery),
            ],
        );
    }
}

//! Fault-model cost gates (EXPERIMENTS.md E8):
//!
//!  1. **Hook overhead**: a session with an ARMED but never-firing
//!     `FaultPlan` vs the empty plan.  Every hook is gated on one
//!     pre-computed `is_empty` branch, so `fault_hooks_overhead` must
//!     stay ≈ 1 — survivability may not tax the fault-free hot path.
//!  2. **Recovery cost**: `failure=restart` with a mid-run worker crash
//!     vs the fault-free run, at identical push totals.
//!     `recovery_vs_faultfree_epochs` is the wall-clock ratio of the
//!     recovered run over the fault-free run for the same epoch budget
//!     (tail-drain wait + warm-start re-read included).
//!  3. **Wire hook overhead** (DESIGN.md §2.0.7): the same armed-inert
//!     discipline on the TCP data plane — a loopback push/drain loop
//!     with a `netdrop`/`netstall` plan that never fires vs no plan.
//!     `net_fault_hooks_overhead` must stay ≈ 1: both hooks sit behind
//!     one `is_empty` branch per send/flush.
//!  4. **Networked recovery cost**: the crash-restart ratio of (2)
//!     measured over `transport=tcp` (real loopback sockets, credit
//!     windows, lane reconnect) — `net_recovery_vs_faultfree_epochs`.
//!
//!     cargo bench --bench fault_recovery [-- --json]
//!     BENCH_QUICK=1 cargo bench --bench fault_recovery -- --json

use std::sync::Arc;
use std::time::Instant;

use asybadmm::bench::{emit_hotpath_json, harness_from_env, json_requested, maybe_list_gates, BenchResult};
use asybadmm::config::{Config, FailurePolicy, TransportKind};
use asybadmm::coordinator::{
    FaultPlan, PushMsg, PushPool, PushReceiver, PushSender, Session, TcpPushSender, TcpTransport,
};
use asybadmm::data::{gen_partitioned, Dataset, WorkerShard};

/// Best-of-N wall time for a full threaded session (min is robust to
/// scheduler noise on the 1-core CI host); asserts exact accounting.
fn timed(cfg: &Config, ds: &Dataset, shards: &[WorkerShard], reps: usize) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = Session::builder(cfg).dataset(ds, shards).run().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        // Fault-free, armed-but-inert, and restart-recovered runs must
        // all land the exact same push totals.
        assert_eq!(r.total_pushes(), cfg.epochs * cfg.n_workers, "pushes lost");
        best = best.min(dt);
    }
    best
}

/// Wall time for `n_windows` windowed push/drain rounds over a real
/// loopback socket pair, with `plan` (possibly armed-but-inert) on the
/// sender.  One window fills the credit cap exactly, then drains, so
/// both variants execute identical send/flush/credit sequences and the
/// ratio isolates the per-call hook cost.
fn net_window_time(plan: Option<Arc<FaultPlan>>, n_windows: usize) -> f64 {
    const WINDOW: usize = 16;
    let transport = TcpTransport::new(1, 1, WINDOW, 2);
    let addr = transport.local_addr();
    let mut tx =
        TcpPushSender::connect_remote(&addr, 0, 1, WINDOW, 2).expect("dial loopback lanes");
    if let Some(p) = plan {
        tx.set_fault_plan(p);
    }
    let mut rx = transport.connect_server(0);
    let mut pool = PushPool::new(256, 32);
    let t0 = Instant::now();
    for round in 0..n_windows {
        for i in 0..WINDOW {
            let msg = PushMsg {
                worker: 0,
                block: 0,
                w: pool.acquire(),
                worker_epoch: round * WINDOW + i,
                z_version_used: 0,
                block_seq: 0,
                sent_at: None,
                recycle: Some(pool.recycler()),
            };
            tx.send(0, msg).expect("loopback send");
        }
        for _ in 0..WINDOW {
            let mut msg = rx.recv().expect("loopback transport ended early");
            msg.recycle_now();
        }
    }
    t0.elapsed().as_secs_f64()
}

fn record(h: &mut asybadmm::bench::Harness, name: &str, per_op_s: f64) {
    h.results.push(BenchResult {
        name: name.to_string(),
        samples: vec![per_op_s],
        mean_s: per_op_s,
        std_s: 0.0,
        p50_s: per_op_s,
        p95_s: per_op_s,
    });
}

fn main() {
    if maybe_list_gates() {
        return;
    }
    let quick = std::env::var("BENCH_QUICK").as_deref() == Ok("1");
    let mut h = harness_from_env();
    println!("== fault hooks + crash recovery ==");

    let mut cfg = Config::tiny_test();
    cfg.epochs = if quick { 300 } else { 1500 };
    let reps = if quick { 3 } else { 5 };
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);

    // Warm (thread spawn, page faults).
    let mut warm = cfg.clone();
    warm.epochs = 50;
    timed(&warm, &ds, &shards, 1);

    // 1. Empty plan vs armed-but-never-firing plan.
    let empty_s = timed(&cfg, &ds, &shards, reps);
    cfg.faults = format!("crash:w0@{}", usize::MAX); // armed, never fires
    let armed_s = timed(&cfg, &ds, &shards, reps);
    let overhead = armed_s / empty_s.max(1e-12);
    record(&mut h, "session, empty fault plan", empty_s);
    record(&mut h, "session, armed inert fault plan", armed_s);
    println!(
        "\nfault hooks ({} epochs x {} workers, best of {reps}):\n\
         \x20 empty plan {empty_s:.4}s | armed {armed_s:.4}s\n\
         \x20 -> fault_hooks_overhead = {overhead:.3}x  (gate: ~1, noise aside)",
        cfg.epochs, cfg.n_workers
    );

    // 2. Restart recovery vs fault-free, same budget and push totals.
    cfg.faults = format!("crash:w1@{}", cfg.epochs / 4);
    cfg.failure = FailurePolicy::Restart;
    let recovered_s = timed(&cfg, &ds, &shards, reps);
    let recovery = recovered_s / empty_s.max(1e-12);
    record(&mut h, "session, mid-run crash + restart", recovered_s);
    println!(
        "\ncrash at epoch {} + warm restart:\n\
         \x20 fault-free {empty_s:.4}s | recovered {recovered_s:.4}s\n\
         \x20 -> recovery_vs_faultfree_epochs = {recovery:.3}x \
         (tail drain + dual warm-start included)",
        cfg.epochs / 4
    );

    // 3. Wire-level hooks: armed-but-never-firing netdrop+netstall plan
    //    vs no plan on a loopback push/drain loop (best-of to shrug off
    //    socket scheduling noise).
    let n_windows = if quick { 100 } else { 400 };
    let inert = Arc::new(
        FaultPlan::parse(&format!(
            "netdrop:w0@{m};netstall:w0@{m}+1ms",
            m = usize::MAX
        ))
        .unwrap(),
    );
    let (mut net_empty_s, mut net_armed_s) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..3 {
        net_empty_s = net_empty_s.min(net_window_time(None, n_windows));
        net_armed_s = net_armed_s.min(net_window_time(Some(inert.clone()), n_windows));
    }
    let net_overhead = net_armed_s / net_empty_s.max(1e-12);
    record(&mut h, "tcp push loop, no fault plan", net_empty_s);
    record(&mut h, "tcp push loop, armed inert net plan", net_armed_s);
    println!(
        "\nwire fault hooks ({n_windows} windows x 16 pushes, loopback, best of 3):\n\
         \x20 no plan {net_empty_s:.4}s | armed {net_armed_s:.4}s\n\
         \x20 -> net_fault_hooks_overhead = {net_overhead:.3}x  (gate: ~1, noise aside)",
    );

    // 4. Crash + restart over the TCP transport: same discipline as
    //    leg 2, but every push crosses a real socket and the restarted
    //    worker re-dials its lanes.
    let mut cfg_net = Config::tiny_test();
    cfg_net.epochs = cfg.epochs;
    cfg_net.transport = TransportKind::Tcp;
    let net_free_s = timed(&cfg_net, &ds, &shards, reps);
    cfg_net.faults = format!("crash:w1@{}", cfg_net.epochs / 4);
    cfg_net.failure = FailurePolicy::Restart;
    let net_recovered_s = timed(&cfg_net, &ds, &shards, reps);
    let net_recovery = net_recovered_s / net_free_s.max(1e-12);
    record(&mut h, "tcp session, fault-free", net_free_s);
    record(&mut h, "tcp session, mid-run crash + restart", net_recovered_s);
    println!(
        "\ncrash at epoch {} + warm restart over transport=tcp:\n\
         \x20 fault-free {net_free_s:.4}s | recovered {net_recovered_s:.4}s\n\
         \x20 -> net_recovery_vs_faultfree_epochs = {net_recovery:.3}x \
         (lane re-dial + tail drain included)",
        cfg_net.epochs / 4
    );

    println!("\n{}", h.csv());

    if json_requested() {
        emit_hotpath_json(
            "fault_recovery",
            &h,
            &[
                ("fault_hooks_overhead", overhead),
                ("recovery_vs_faultfree_epochs", recovery),
                ("net_fault_hooks_overhead", net_overhead),
                ("net_recovery_vs_faultfree_epochs", net_recovery),
            ],
        );
    }
}

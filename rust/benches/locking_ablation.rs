//! E4 ablation: lock-free block-wise updates (this paper) vs the
//! single-global-lock full-vector design of prior asynchronous ADMMs —
//! the motivating claim of §1.
//!
//! Four measurements:
//!  1. store-level read throughput: the seqlock double-buffer BlockStore
//!     vs the RwLock copy-under-lock baseline under 8 concurrent readers
//!     + 1 writer per block (the hot-path gate: seqlock must win ≥ 2×),
//!  2. raw transport enqueue/drain throughput: the per-worker SPSC ring
//!     transport vs the shared bounded-mpsc channel, 4 producers → 1
//!     draining server, pooled buffers (the `ring_vs_mpsc_enqueue` gate
//!     in BENCH_hotpath.json),
//!  3. threaded wall-clock throughput (iterations/s) of the async
//!     session (under both transports) vs run_locked_admm at identical
//!     budgets (on a multi-core host the gap widens with p; on a 1-2
//!     core machine it mostly shows overhead parity), and
//!  4. the DES with per-block servers vs ONE server shard with service
//!     time scaled by |N(i)| (full-vector application) — the
//!     architecture-level serialization cost, core-count independent.
//!
//!     cargo bench --bench locking_ablation [-- --json]
//!     BENCH_QUICK=1 cargo bench --bench locking_ablation

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

use asybadmm::baselines::run_locked_admm;
use asybadmm::bench::{emit_hotpath_json, harness_from_env, json_requested, maybe_list_gates, BenchResult};
use asybadmm::config::{Config, TransportKind};
use asybadmm::coordinator::{
    make_transport, push_inflight, BlockStore, PushMsg, PushPool, RwBlockStore, Session,
    TcpTransport, Transport,
};
use asybadmm::data::gen_partitioned;
use asybadmm::sim::{run_sim, CostModel};

/// Store API surface the ablation needs, implemented by both stores.
trait Store: Sync {
    fn read_into(&self, j: usize, out: &mut [f32]) -> u64;
    fn write(&self, j: usize, data: &[f32]) -> u64;
}

impl Store for BlockStore {
    fn read_into(&self, j: usize, out: &mut [f32]) -> u64 {
        BlockStore::read_into(self, j, out)
    }
    fn write(&self, j: usize, data: &[f32]) -> u64 {
        BlockStore::write(self, j, data)
    }
}

impl Store for RwBlockStore {
    fn read_into(&self, j: usize, out: &mut [f32]) -> u64 {
        RwBlockStore::read_into(self, j, out)
    }
    fn write(&self, j: usize, data: &[f32]) -> u64 {
        RwBlockStore::write(self, j, data)
    }
}

/// Reads/s across `readers` reader threads while one writer hammers
/// every block round-robin (i.e. 1 writer per block at any instant).
fn read_throughput<S: Store>(
    store: &S,
    n_blocks: usize,
    db: usize,
    readers: usize,
    dur: Duration,
) -> f64 {
    let stop = AtomicBool::new(false);
    let total = AtomicU64::new(0);
    std::thread::scope(|scope| {
        let (stop, total) = (&stop, &total);
        for t in 0..readers {
            scope.spawn(move || {
                let mut buf = vec![0.0f32; db];
                let mut n = 0u64;
                let mut j = t;
                while !stop.load(Ordering::Relaxed) {
                    store.read_into(j % n_blocks, &mut buf);
                    std::hint::black_box(&buf);
                    j += 1;
                    n += 1;
                }
                total.fetch_add(n, Ordering::Relaxed);
            });
        }
        scope.spawn(move || {
            let data = vec![1.0f32; db];
            let mut j = 0usize;
            while !stop.load(Ordering::Relaxed) {
                store.write(j % n_blocks, &data);
                j += 1;
            }
        });
        std::thread::sleep(dur);
        stop.store(true, Ordering::Relaxed);
    });
    total.load(Ordering::Relaxed) as f64 / dur.as_secs_f64()
}

/// Raw transport throughput: `workers` producer threads blast pooled
/// pushes at one server endpoint that drains and recycles them — the
/// enqueue/dequeue path in isolation (no ADMM math, no allocation in
/// steady state).
fn push_throughput(kind: TransportKind, workers: usize, per_worker: usize, db: usize) -> f64 {
    let transport = make_transport(kind, workers, 1, push_inflight(workers), 1);
    let total = workers * per_worker;
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for w in 0..workers {
            let mut tx = transport.connect_worker(w);
            scope.spawn(move || {
                let mut pool = PushPool::new(db, 32);
                for i in 0..per_worker {
                    let buf = pool.acquire();
                    let msg = PushMsg {
                        worker: w,
                        block: 0,
                        w: buf,
                        worker_epoch: i,
                        z_version_used: 0,
                        block_seq: 0,
                        sent_at: None,
                        recycle: Some(pool.recycler()),
                    };
                    tx.send(0, msg).unwrap();
                }
            });
        }
        let mut rx = transport.connect_server(0);
        for _ in 0..total {
            let mut msg = rx.recv().expect("transport ended early");
            msg.recycle_now();
        }
    });
    total as f64 / t0.elapsed().as_secs_f64()
}

/// Record an externally-timed measurement (seconds per op) so it lands
/// in the harness's CSV/JSON alongside closure-timed benches.
fn record(h: &mut asybadmm::bench::Harness, name: &str, per_op_s: f64) {
    h.results.push(BenchResult {
        name: name.to_string(),
        samples: vec![per_op_s],
        mean_s: per_op_s,
        std_s: 0.0,
        p50_s: per_op_s,
        p95_s: per_op_s,
    });
}

fn main() {
    if maybe_list_gates() {
        return;
    }
    let quick = std::env::var("BENCH_QUICK").as_deref() == Ok("1");
    let mut h = harness_from_env();
    println!("== E4: lock-free block-wise vs global-lock full-vector ==");

    // 1. Store microbench: seqlock vs RwLock under readers + writer.
    let (n_blocks, db, readers) = (4usize, 256usize, 8usize);
    let dur = Duration::from_millis(if quick { 80 } else { 400 });
    // Warm both stores (thread spawn amortization, page faults).
    let seq_store = BlockStore::new(n_blocks, db);
    let rw_store = RwBlockStore::new(n_blocks, db);
    read_throughput(&seq_store, n_blocks, db, readers, Duration::from_millis(20));
    read_throughput(&rw_store, n_blocks, db, readers, Duration::from_millis(20));
    let seq_rps = read_throughput(&seq_store, n_blocks, db, readers, dur);
    let rw_rps = read_throughput(&rw_store, n_blocks, db, readers, dur);
    let ratio = seq_rps / rw_rps.max(1.0);
    record(&mut h, "seqlock store read (8r+1w, db=256)", 1.0 / seq_rps.max(1.0));
    record(&mut h, "rwlock store read (8r+1w, db=256)", 1.0 / rw_rps.max(1.0));
    println!(
        "store reads ({readers} readers + 1 writer, {n_blocks} blocks x db={db}):\n\
         \x20 seqlock {:>10.0} reads/s\n\
         \x20 rwlock  {:>10.0} reads/s\n\
         \x20 -> seqlock/rwlock = {ratio:.2}x  (gate: >= 2.0x)",
        seq_rps, rw_rps
    );

    // 2. Raw transport enqueue/drain: per-worker SPSC rings vs the
    //    shared bounded-mpsc channel (ROADMAP "lock-free server queues"),
    //    plus the loopback-TCP lanes so the socket transport's frame
    //    encode + syscall + credit-window cost is tracked against the
    //    in-process fast path it must stand in for across machines.
    let msgs = if quick { 2_000 } else { 20_000 };
    // Warm each transport once (connection setup, listener accept and
    // first-allocation costs land outside the measured run).
    for kind in [TransportKind::Mpsc, TransportKind::SpscRing, TransportKind::Tcp] {
        push_throughput(kind, 4, msgs / 10 + 1, 256);
    }
    let mpsc_rate = push_throughput(TransportKind::Mpsc, 4, msgs, 256);
    let ring_rate = push_throughput(TransportKind::SpscRing, 4, msgs, 256);
    let tcp_rate = push_throughput(TransportKind::Tcp, 4, msgs, 256);
    let enqueue_ratio = ring_rate / mpsc_rate.max(1.0);
    let tcp_ratio = tcp_rate / ring_rate.max(1.0);
    record(&mut h, "mpsc transport push (4w->1s, db=256)", 1.0 / mpsc_rate.max(1.0));
    record(&mut h, "ring transport push (4w->1s, db=256)", 1.0 / ring_rate.max(1.0));
    record(&mut h, "tcp transport push (4w->1s, db=256)", 1.0 / tcp_rate.max(1.0));
    println!(
        "\ntransport pushes (4 producers -> 1 draining server, db=256):\n\
         \x20 mpsc {:>10.0} pushes/s\n\
         \x20 ring {:>10.0} pushes/s\n\
         \x20 tcp  {:>10.0} pushes/s  (loopback sockets)\n\
         \x20 -> ring/mpsc = {enqueue_ratio:.2}x  (gate; <1 expected only on 1-core hosts)\n\
         \x20 -> tcp/ring  = {tcp_ratio:.2}x  (gate; <1 expected — this is the price of a wire)",
        mpsc_rate, ring_rate, tcp_rate
    );

    // 2b. Credit coalescing on the tcp reverse path: v1 acked every
    //     decoded push frame 1:1; v2 returns one cumulative
    //     Credit{frames, hint} per drain pass (flush threshold
    //     ceil(cap_b/2), plus an idle flush for liveness).  The
    //     `credit_coalescing_frames` gate is credit frames per push
    //     frame at batch=2 — 1.0 is the old per-frame ack wire, the
    //     threshold puts steady state near 0.25.
    //     Windowed send/drain keeps the measurement deterministic: each
    //     round fills the credit window exactly (cap=16 msgs = 8 batch-2
    //     frames), lets loopback deliver, then drains — so credit
    //     frames per window are set by the flush threshold, not by how
    //     the scheduler interleaved a racing producer.
    let n_windows = if quick { 50 } else { 200 };
    let window_msgs = 16usize;
    let (credit_ratio, credit_w) = {
        let transport = TcpTransport::new(1, 1, window_msgs, 2);
        let mut tx = transport.connect_worker(0);
        let mut rx = transport.connect_server(0);
        let mut pool = PushPool::new(256, 32);
        for round in 0..n_windows {
            for i in 0..window_msgs {
                let buf = pool.acquire();
                let msg = PushMsg {
                    worker: 0,
                    block: 0,
                    w: buf,
                    worker_epoch: round * window_msgs + i,
                    z_version_used: 0,
                    block_seq: 0,
                    sent_at: None,
                    recycle: Some(pool.recycler()),
                };
                tx.send(0, msg).unwrap();
            }
            std::thread::sleep(Duration::from_micros(500));
            for _ in 0..window_msgs {
                let mut msg = rx.recv().expect("tcp transport ended early");
                msg.recycle_now();
            }
        }
        let w = transport.wire_snapshot();
        assert_eq!(
            w.msgs_in as usize,
            n_windows * window_msgs,
            "wire counters missed messages"
        );
        (w.credit_frames_out as f64 / (w.push_frames_in as f64).max(1.0), w)
    };
    record(&mut h, "tcp credit coalescing (1w->1s, batch=2)", credit_ratio);
    println!(
        "\ncredit coalescing (1 producer -> 1 draining server, batch=2, cap=16):\n\
         \x20 push frames in    {:>8}  ({} msgs)\n\
         \x20 credit frames out {:>8}  ({} frame credits returned)\n\
         \x20 -> credits/pushes = {credit_ratio:.3}  (gate: < 0.55; per-frame acks were 1.0)",
        credit_w.push_frames_in, credit_w.msgs_in, credit_w.credit_frames_out, credit_w.credits_out
    );

    // 3. Wall-clock (threaded), async session under both transports.
    let mut cfg = Config::small();
    cfg.samples = if quick { 512 } else { 2048 };
    cfg.epochs = if quick { 100 } else { 400 };
    cfg.log_every = 100_000;
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);

    let t0 = std::time::Instant::now();
    let r_free = Session::builder(&cfg).dataset(&ds, &shards).run().unwrap();
    let t_free = t0.elapsed().as_secs_f64();
    let block_updates_free = cfg.epochs * cfg.n_workers;

    let mut cfg_ring = cfg.clone();
    cfg_ring.transport = TransportKind::SpscRing;
    let t0 = std::time::Instant::now();
    let r_ring = Session::builder(&cfg_ring).dataset(&ds, &shards).run().unwrap();
    let t_ring = t0.elapsed().as_secs_f64();

    // The locked baseline does full-vector epochs (|N(i)| block updates
    // per iteration): match total block updates.
    let mut cfg_locked = cfg.clone();
    cfg_locked.epochs = cfg.epochs / cfg.blocks_per_worker.max(1);
    let t0 = std::time::Instant::now();
    let r_locked = run_locked_admm(&cfg_locked, &ds, &shards).unwrap();
    let t_locked = t0.elapsed().as_secs_f64();
    let block_updates_locked = cfg_locked.epochs * cfg.n_workers * cfg.blocks_per_worker;

    let free_rate = block_updates_free as f64 / t_free;
    let ring_threaded_rate = block_updates_free as f64 / t_ring;
    let locked_rate = block_updates_locked as f64 / t_locked;
    record(&mut h, "threaded lock-free block-update (mpsc)", 1.0 / free_rate.max(1.0));
    record(&mut h, "threaded lock-free block-update (ring)", 1.0 / ring_threaded_rate.max(1.0));
    record(&mut h, "threaded global-lock block-update", 1.0 / locked_rate.max(1.0));
    println!(
        "threaded  lock-free (mpsc): {:>8.0} block-updates/s (obj {:.5})",
        free_rate,
        r_free.final_objective.total()
    );
    println!(
        "threaded  lock-free (ring): {:>8.0} block-updates/s (obj {:.5})",
        ring_threaded_rate,
        r_ring.final_objective.total()
    );
    println!(
        "threaded  global-lock: {:>8.0} block-updates/s (obj {:.5})",
        locked_rate,
        r_locked.final_objective.total()
    );

    // 4. Architectural serialization via DES: multi-server block-wise
    //    vs single server whose service time covers a full-vector apply.
    println!("\nDES (architecture-level, virtual time to k=50):");
    let k = 50;
    let mut des_gap_p32 = 0.0;
    for p in [4usize, 16, 32] {
        let mut c = Config::default();
        c.samples = if quick { 1024 } else { 4096 };
        c.epochs = k;
        c.n_workers = p;
        c.log_every = 100_000;
        let (ds, shards) = gen_partitioned(&c.synth_spec(), p);

        let base_cost = CostModel {
            compute_fixed_s: 1e-5,
            compute_per_row_s: 1e-6,
            server_service_s: 3e-5,
            net_mean_s: 1e-4,
            ..CostModel::default()
        };
        let r_blockwise = run_sim(&c, &ds, &shards, &base_cost).unwrap();

        // Global-lock model: ONE server (all blocks behind one latch)
        // and each apply covers |N(i)| blocks of work.
        let mut c1 = c.clone();
        c1.n_servers = 1;
        let locked_cost = CostModel {
            server_service_s: base_cost.server_service_s * c.blocks_per_worker as f64,
            ..base_cost
        };
        let r_locked = run_sim(&c1, &ds, &shards, &locked_cost).unwrap();

        let gap = r_locked.time_to_epoch[k] / r_blockwise.time_to_epoch[k].max(1e-12);
        if p == 32 {
            des_gap_p32 = gap;
        }
        println!(
            "  p={p:>2}: block-wise {:>8.3}s vs global-lock {:>8.3}s  ({gap:.2}x, queue {} vs {})",
            r_blockwise.time_to_epoch[k],
            r_locked.time_to_epoch[k],
            r_blockwise.max_queue,
            r_locked.max_queue,
        );
    }
    println!("\n(expected: the global-lock column grows with p — the paper's motivating gap)");

    if json_requested() {
        emit_hotpath_json(
            "locking_ablation",
            &h,
            &[
                ("seqlock_reads_per_s", seq_rps),
                ("rwlock_reads_per_s", rw_rps),
                ("seqlock_vs_rwlock", ratio),
                ("mpsc_push_per_s", mpsc_rate),
                ("ring_push_per_s", ring_rate),
                ("tcp_push_per_s", tcp_rate),
                ("ring_vs_mpsc_enqueue", enqueue_ratio),
                ("tcp_loopback_vs_ring_enqueue", tcp_ratio),
                ("credit_coalescing_frames", credit_ratio),
                ("threaded_lockfree_updates_per_s", free_rate),
                ("threaded_ring_updates_per_s", ring_threaded_rate),
                ("threaded_globallock_updates_per_s", locked_rate),
                ("des_gap_p32", des_gap_p32),
            ],
        );
    }
}

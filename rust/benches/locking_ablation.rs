//! E4 ablation: lock-free block-wise updates (this paper) vs the
//! single-global-lock full-vector design of prior asynchronous ADMMs —
//! the motivating claim of §1.
//!
//! Two measurements:
//!  1. threaded wall-clock throughput (iterations/s) of run_async vs
//!     run_locked_admm at identical budgets (on a multi-core host the
//!     gap widens with p; on this 1-core machine it mostly shows
//!     overhead parity), and
//!  2. the DES with per-block servers vs ONE server shard with service
//!     time scaled by |N(i)| (full-vector application) — the
//!     architecture-level serialization cost, core-count independent.

use asybadmm::baselines::run_locked_admm;
use asybadmm::config::Config;
use asybadmm::coordinator::run_async;
use asybadmm::data::gen_partitioned;
use asybadmm::sim::{run_sim, CostModel};

fn main() {
    let quick = std::env::var("BENCH_QUICK").as_deref() == Ok("1");
    let mut cfg = Config::small();
    cfg.samples = if quick { 512 } else { 2048 };
    cfg.epochs = if quick { 100 } else { 400 };
    cfg.log_every = 100_000;
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);

    println!("== E4: lock-free block-wise vs global-lock full-vector ==");

    // 1. Wall-clock (threaded).
    let t0 = std::time::Instant::now();
    let r_free = run_async(&cfg, &ds, &shards).unwrap();
    let t_free = t0.elapsed().as_secs_f64();
    let block_updates_free = cfg.epochs * cfg.n_workers;

    // The locked baseline does full-vector epochs (|N(i)| block updates
    // per iteration): match total block updates.
    let mut cfg_locked = cfg.clone();
    cfg_locked.epochs = cfg.epochs / cfg.blocks_per_worker.max(1);
    let t0 = std::time::Instant::now();
    let r_locked = run_locked_admm(&cfg_locked, &ds, &shards).unwrap();
    let t_locked = t0.elapsed().as_secs_f64();
    let block_updates_locked = cfg_locked.epochs * cfg.n_workers * cfg.blocks_per_worker;

    println!(
        "threaded  lock-free : {:>8.0} block-updates/s (obj {:.5})",
        block_updates_free as f64 / t_free,
        r_free.final_objective.total()
    );
    println!(
        "threaded  global-lock: {:>8.0} block-updates/s (obj {:.5})",
        block_updates_locked as f64 / t_locked,
        r_locked.final_objective.total()
    );

    // 2. Architectural serialization via DES: multi-server block-wise
    //    vs single server whose service time covers a full-vector apply.
    println!("\nDES (architecture-level, virtual time to k=50):");
    let k = 50;
    for p in [4usize, 16, 32] {
        let mut c = Config::default();
        c.samples = if quick { 1024 } else { 4096 };
        c.epochs = k;
        c.n_workers = p;
        c.log_every = 100_000;
        let (ds, shards) = gen_partitioned(&c.synth_spec(), p);

        let base_cost = CostModel {
            compute_fixed_s: 1e-5,
            compute_per_row_s: 1e-6,
            server_service_s: 3e-5,
            net_mean_s: 1e-4,
            chunk_rows: 0,
            per_chunk_s: 0.0,
            compute_jitter: 0.0,
        };
        let r_blockwise = run_sim(&c, &ds, &shards, &base_cost).unwrap();

        // Global-lock model: ONE server (all blocks behind one latch)
        // and each apply covers |N(i)| blocks of work.
        let mut c1 = c.clone();
        c1.n_servers = 1;
        let locked_cost = CostModel {
            server_service_s: base_cost.server_service_s * c.blocks_per_worker as f64,
            ..base_cost
        };
        let r_locked = run_sim(&c1, &ds, &shards, &locked_cost).unwrap();

        println!(
            "  p={p:>2}: block-wise {:>8.3}s vs global-lock {:>8.3}s  ({:.2}x, queue {} vs {})",
            r_blockwise.time_to_epoch[k],
            r_locked.time_to_epoch[k],
            r_locked.time_to_epoch[k] / r_blockwise.time_to_epoch[k].max(1e-12),
            r_blockwise.max_queue,
            r_locked.max_queue,
        );
    }
    println!("\n(expected: the global-lock column grows with p — the paper's motivating gap)");
}

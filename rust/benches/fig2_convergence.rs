//! E1/E2 bench: regenerate the Fig. 2 convergence curves (objective vs
//! iterations and vs virtual time) at bench scale and assert their
//! qualitative shape: every worker count converges, and more workers
//! reach a given objective sooner in (virtual) time.

use asybadmm::config::Config;
use asybadmm::coordinator::{Algo, Session};
use asybadmm::data::gen_virtual_partitioned;
use asybadmm::sim::CostModel;

fn main() {
    if asybadmm::bench::maybe_list_gates() {
        return;
    }
    let quick = std::env::var("BENCH_QUICK").as_deref() == Ok("1");
    let mut base = Config::default();
    base.epochs = if quick { 30 } else { 100 };
    base.log_every = 5;
    base.samples = if quick { 1024 } else { 4096 };

    println!("== Fig. 2: convergence under asynchrony ==");
    let mut finals = Vec::new();
    let mut t_to_target = Vec::new();
    let cost = CostModel {
        compute_fixed_s: 1e-5,
        compute_per_row_s: 2e-5,
        server_service_s: 2e-5,
        net_mean_s: 2e-4,
        compute_jitter: 0.1,
        ..CostModel::default()
    };
    for p in [1usize, 4, 16] {
        let mut cfg = base.clone();
        cfg.n_workers = p;
        let (ds, shards) = gen_virtual_partitioned(&cfg.synth_spec(), 32, p);
        let r = Session::builder(&cfg)
            .dataset(&ds, &shards)
            .algo(Algo::Sim(cost))
            .run()
            .unwrap();
        let first = r.samples.first().unwrap().objective;
        let target = first - 0.5 * (first - r.final_objective.total());
        let t_half = r
            .samples
            .iter()
            .find(|s| s.objective <= target)
            .map(|s| s.time_s)
            .unwrap_or(r.elapsed_s);
        println!(
            "p={p:>2}: obj {first:.5} -> {:.5}, half-way at {t_half:.2} virtual s",
            r.final_objective.total()
        );
        finals.push(r.final_objective.total());
        t_to_target.push(t_half);
    }
    // Fig 2(a) shape: all curves converge to the same neighborhood.
    let spread = finals.iter().cloned().fold(f64::MIN, f64::max)
        - finals.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread < 0.05, "worker counts disagree on the optimum: {finals:?}");
    // Fig 2(b) shape: more workers = faster in wall(virtual)-clock.
    assert!(
        t_to_target[2] < t_to_target[0],
        "16 workers not faster than 1: {t_to_target:?}"
    );
    println!("shape checks passed (consistent optimum; asynchrony speeds wall-clock).");
}

//! Wire-format hot path (DESIGN.md §2.0.5–2.0.6): encode/decode
//! throughput of the length-prefixed push frames the networked runtime
//! puts on every worker→server socket, plus the pull-plane delta
//! encoding ratio.
//!
//! The TCP transport's per-push budget is one body serialization on the
//! sender (`put_push_body` into a reused frame buffer) and one
//! bounds-checked body parse on the receiver (`take_push_body` out of a
//! pooled buffer).  This bench isolates both from the socket so a
//! serialization regression is attributable separately from kernel or
//! syscall noise — the `tcp_frame_encode_throughput` gate in
//! BENCH_hotpath.json (pushes encoded per second, batched frames).
//!
//! The second section measures the `PullResp` v2 encoder on an ADMM-like
//! sparse refresh (a few lanes of z̃ move per block between polls): the
//! `delta_pull_bytes` gate is sparse-encoded bytes over the all-dense
//! bytes the v1 wire would have shipped, asserted bit-identical after
//! reconstruction.
//!
//!     cargo bench --bench net_wire [-- --json]
//!     BENCH_QUICK=1 cargo bench --bench net_wire

use asybadmm::bench::{emit_hotpath_json, harness_from_env, json_requested, maybe_list_gates};
use asybadmm::coordinator::{wire, PushMsg};
use asybadmm::util::rng::Rng;
use asybadmm::util::AlignedBuf;

/// One pending slot's worth of pushes, shaped like the threaded run:
/// batch messages for one server, paper-scale block width.
fn make_batch(batch: usize, db: usize) -> Vec<PushMsg> {
    let mut rng = Rng::new(7);
    (0..batch)
        .map(|i| PushMsg {
            worker: i % 4,
            block: rng.below(64),
            w: (0..db).map(|_| rng.normal_f32(0.0, 1.0)).collect::<Vec<f32>>().into(),
            worker_epoch: i,
            z_version_used: rng.next_u64(),
            block_seq: i as u64 + 1,
            sent_at: None,
            recycle: None,
        })
        .collect()
}

/// Encode `msgs` as the sender does: one `PushBatch` envelope (or a
/// bare `Push` for batch=1) into a reused buffer.
fn encode_into(buf: &mut Vec<u8>, msgs: &[PushMsg]) {
    buf.clear();
    let start = if msgs.len() == 1 {
        wire::begin_frame(buf, wire::kind::PUSH)
    } else {
        let s = wire::begin_frame(buf, wire::kind::PUSH_BATCH);
        wire::put_u32(buf, msgs.len() as u32);
        s
    };
    for m in msgs {
        wire::put_push_body(buf, m);
    }
    wire::end_frame(buf, start);
}

fn main() {
    if maybe_list_gates() {
        return;
    }
    let mut h = harness_from_env();
    println!("== net_wire: push-frame encode/decode (no sockets) ==");

    let (batch, db) = (8usize, 256usize);
    let msgs = make_batch(batch, db);
    let mut buf = Vec::with_capacity(wire::HEADER + batch * (36 + 4 * db));

    let encode_mean_s = h
        .bench("wire encode (batch=8, db=256)", || {
            encode_into(&mut buf, &msgs);
            std::hint::black_box(buf.as_slice());
        })
        .mean_s;
    let encode_rate = batch as f64 / encode_mean_s.max(1e-12);
    let frame_bytes = buf.len();

    // Decode path: envelope read + cursor parse + body copies, the
    // receiver's cost per frame (allocating like the lane pool's miss
    // path, the conservative bound).
    encode_into(&mut buf, &msgs);
    let decode_mean_s = h
        .bench("wire decode (batch=8, db=256)", || {
            let mut slice = buf.as_slice();
            let (k, payload) = wire::read_frame(&mut slice).unwrap().unwrap();
            let mut cur = wire::Cursor::new(k, &payload).unwrap();
            let count = cur.u32("count").unwrap() as usize;
            for _ in 0..count {
                let p = wire::take_push_body(&mut cur, &mut |n| AlignedBuf::zeroed(n)).unwrap();
                std::hint::black_box(&p);
            }
            cur.finish().unwrap();
        })
        .mean_s;
    let decode_rate = batch as f64 / decode_mean_s.max(1e-12);

    println!(
        "\npush frames ({batch} bodies x db={db}, {frame_bytes} bytes/frame):\n\
         \x20 encode {:>12.0} pushes/s  ({:.2} GB/s)\n\
         \x20 decode {:>12.0} pushes/s\n\
         \x20 (gate: tcp_frame_encode_throughput — serialization must stay far\n\
         \x20  above the socket rate the locking_ablation tcp leg measures)",
        encode_rate,
        encode_rate / batch as f64 * frame_bytes as f64 / 1e9,
        decode_rate
    );

    // -- pull-plane delta encoding (DESIGN.md §2.0.6) -----------------
    // A mirror poll after one prox round touches a handful of lanes per
    // block (sparse dual/primal updates); model ~10% density across 64
    // paper-scale blocks and measure what the v2 encoder ships vs the
    // v1 all-dense wire.  Reconstruction is checked bit-for-bit so the
    // ratio can never be bought with lossy encoding.
    let n_blocks = 64usize;
    let changed_lanes = db / 10;
    let mut rng = Rng::new(11);
    let base: Vec<Vec<f32>> = (0..n_blocks)
        .map(|_| (0..db).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let mut cur: Vec<Vec<f32>> = base.clone();
    for blk in cur.iter_mut() {
        for _ in 0..changed_lanes {
            let lane = rng.below(db);
            blk[lane] += rng.normal_f32(0.0, 0.1);
        }
    }
    let (mut idx, mut vals) = (Vec::new(), Vec::new());
    let mut sparse_buf = Vec::new();
    let mut dense_buf = Vec::new();
    let delta_mean_s = h
        .bench("pull delta encode (64 blocks, ~10% lanes changed)", || {
            sparse_buf.clear();
            dense_buf.clear();
            for j in 0..n_blocks {
                wire::diff_block(&base[j], &cur[j], &mut idx, &mut vals);
                if wire::sparse_saves_bytes(idx.len(), db) {
                    wire::put_pull_block_sparse(&mut sparse_buf, j as u32, 2, 1, &idx, &vals);
                } else {
                    wire::put_pull_block_dense(&mut sparse_buf, j as u32, 2, &cur[j]);
                }
                wire::put_pull_block_dense(&mut dense_buf, j as u32, 2, &cur[j]);
            }
            std::hint::black_box((sparse_buf.len(), dense_buf.len()));
        })
        .mean_s;
    let delta_rate = n_blocks as f64 / delta_mean_s.max(1e-12);
    let delta_pull_bytes = sparse_buf.len() as f64 / dense_buf.len() as f64;

    // Reconstruct every block from the sparse stream and demand bit
    // identity with the dense truth.
    {
        let mut payload = Vec::new();
        wire::put_u32(&mut payload, n_blocks as u32);
        payload.extend_from_slice(&sparse_buf);
        let mut cursor = wire::Cursor::new(wire::kind::PULL_RESP, &payload).unwrap();
        let count = cursor.u32("count").unwrap() as usize;
        assert_eq!(count, n_blocks);
        for _ in 0..count {
            let b = wire::take_pull_block(&mut cursor).unwrap();
            let mut rebuilt = base[b.block].clone();
            match b.body {
                wire::WirePullBody::Dense(d) => rebuilt.copy_from_slice(&d),
                wire::WirePullBody::Sparse { idx, vals, .. } => {
                    wire::apply_sparse_patch(&mut rebuilt, &idx, &vals).unwrap()
                }
            }
            let same = rebuilt
                .iter()
                .zip(&cur[b.block])
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "sparse reconstruction diverged on block {}", b.block);
        }
        cursor.finish().unwrap();
    }

    println!(
        "\npull delta ({n_blocks} blocks x db={db}, ~{changed_lanes} lanes changed):\n\
         \x20 encode {:>12.0} blocks/s\n\
         \x20 bytes  {:>12} sparse vs {} dense  (ratio {:.3})\n\
         \x20 (gate: delta_pull_bytes < 0.5 — sparse deltas must at least halve\n\
         \x20  pull bandwidth on a ~10%-density refresh)",
        delta_rate,
        sparse_buf.len(),
        dense_buf.len(),
        delta_pull_bytes
    );

    if json_requested() {
        emit_hotpath_json(
            "net_wire",
            &h,
            &[
                ("tcp_frame_encode_throughput", encode_rate),
                ("tcp_frame_decode_throughput", decode_rate),
                ("frame_bytes_batch8_db256", frame_bytes as f64),
                ("delta_pull_bytes", delta_pull_bytes),
                ("delta_pull_encode_blocks_per_s", delta_rate),
            ],
        );
    }
}

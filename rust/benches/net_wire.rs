//! Wire-format hot path (DESIGN.md §2.0.5): encode/decode throughput
//! of the length-prefixed push frames the networked runtime puts on
//! every worker→server socket.
//!
//! The TCP transport's per-push budget is one body serialization on the
//! sender (`put_push_body` into a reused frame buffer) and one
//! bounds-checked body parse on the receiver (`take_push_body` out of a
//! pooled buffer).  This bench isolates both from the socket so a
//! serialization regression is attributable separately from kernel or
//! syscall noise — the `tcp_frame_encode_throughput` gate in
//! BENCH_hotpath.json (pushes encoded per second, batched frames).
//!
//!     cargo bench --bench net_wire [-- --json]
//!     BENCH_QUICK=1 cargo bench --bench net_wire

use asybadmm::bench::{emit_hotpath_json, harness_from_env, json_requested, maybe_list_gates};
use asybadmm::coordinator::{wire, PushMsg};
use asybadmm::util::rng::Rng;
use asybadmm::util::AlignedBuf;

/// One pending slot's worth of pushes, shaped like the threaded run:
/// batch messages for one server, paper-scale block width.
fn make_batch(batch: usize, db: usize) -> Vec<PushMsg> {
    let mut rng = Rng::new(7);
    (0..batch)
        .map(|i| PushMsg {
            worker: i % 4,
            block: rng.below(64),
            w: (0..db).map(|_| rng.normal_f32(0.0, 1.0)).collect::<Vec<f32>>().into(),
            worker_epoch: i,
            z_version_used: rng.next_u64(),
            block_seq: i as u64 + 1,
            sent_at: None,
            recycle: None,
        })
        .collect()
}

/// Encode `msgs` as the sender does: one `PushBatch` envelope (or a
/// bare `Push` for batch=1) into a reused buffer.
fn encode_into(buf: &mut Vec<u8>, msgs: &[PushMsg]) {
    buf.clear();
    let start = if msgs.len() == 1 {
        wire::begin_frame(buf, wire::kind::PUSH)
    } else {
        let s = wire::begin_frame(buf, wire::kind::PUSH_BATCH);
        wire::put_u32(buf, msgs.len() as u32);
        s
    };
    for m in msgs {
        wire::put_push_body(buf, m);
    }
    wire::end_frame(buf, start);
}

fn main() {
    if maybe_list_gates() {
        return;
    }
    let mut h = harness_from_env();
    println!("== net_wire: push-frame encode/decode (no sockets) ==");

    let (batch, db) = (8usize, 256usize);
    let msgs = make_batch(batch, db);
    let mut buf = Vec::with_capacity(wire::HEADER + batch * (36 + 4 * db));

    let encode_mean_s = h
        .bench("wire encode (batch=8, db=256)", || {
            encode_into(&mut buf, &msgs);
            std::hint::black_box(buf.as_slice());
        })
        .mean_s;
    let encode_rate = batch as f64 / encode_mean_s.max(1e-12);
    let frame_bytes = buf.len();

    // Decode path: envelope read + cursor parse + body copies, the
    // receiver's cost per frame (allocating like the lane pool's miss
    // path, the conservative bound).
    encode_into(&mut buf, &msgs);
    let decode_mean_s = h
        .bench("wire decode (batch=8, db=256)", || {
            let mut slice = buf.as_slice();
            let (k, payload) = wire::read_frame(&mut slice).unwrap().unwrap();
            let mut cur = wire::Cursor::new(k, &payload).unwrap();
            let count = cur.u32("count").unwrap() as usize;
            for _ in 0..count {
                let p = wire::take_push_body(&mut cur, &mut |n| AlignedBuf::zeroed(n)).unwrap();
                std::hint::black_box(&p);
            }
            cur.finish().unwrap();
        })
        .mean_s;
    let decode_rate = batch as f64 / decode_mean_s.max(1e-12);

    println!(
        "\npush frames ({batch} bodies x db={db}, {frame_bytes} bytes/frame):\n\
         \x20 encode {:>12.0} pushes/s  ({:.2} GB/s)\n\
         \x20 decode {:>12.0} pushes/s\n\
         \x20 (gate: tcp_frame_encode_throughput — serialization must stay far\n\
         \x20  above the socket rate the locking_ablation tcp leg measures)",
        encode_rate,
        encode_rate / batch as f64 * frame_bytes as f64 / 1e9,
        decode_rate
    );

    if json_requested() {
        emit_hotpath_json(
            "net_wire",
            &h,
            &[
                ("tcp_frame_encode_throughput", encode_rate),
                ("tcp_frame_decode_throughput", decode_rate),
                ("frame_bytes_batch8_db256", frame_bytes as f64),
            ],
        );
    }
}

//! Placement + drain-policy + adaptive-runtime scaling under a
//! Zipf-skewed block workload.
//!
//! The synthetic workload's hot shared blocks have low indices, so the
//! default contiguous placement concentrates the whole Zipf head on
//! shard 0 — the server-side serialization the placement/drain layer
//! (PR 4) and the adaptive runtime (this PR) exist to break.  Six
//! measurements:
//!
//!  1. **Static skew**: max/mean shard load (load = Σ |𝒩(j)| over owned
//!     blocks) under contiguous vs hash vs degree placement — the
//!     `degree_vs_contiguous_skew` gate (how much better the
//!     degree-aware packing balances the hot head).
//!  2. **Enqueue-to-apply throughput**: workers blast pooled pushes
//!     routed by the placement while server threads drain under
//!     `owned` vs `steal` — the `steal_vs_owned_drain` gate
//!     (`placement=degree drain=steal` vs `placement=contiguous
//!     drain=owned`; on a 1-core host expect ≈1, on multi-core > 1).
//!  3. **Batched ring slots**: the same pipeline at `batch=8` vs
//!     `batch=1` (`ring_batch_amortization`) — per-slot atomics
//!     amortized over whole w-block batches.
//!  4. **Dynamic re-placement**: the same pipeline starting from the
//!     contiguous map with the runtime rebalancer migrating hot blocks
//!     from observed rates — the `dynamic_vs_degree_skew` gate
//!     (applied-push max/mean imbalance, dynamic / degree; ≤ ~1 means
//!     the adaptive map matched or beat the static degree prior, and
//!     it must be well below the contiguous baseline).
//!  5. **Elastic server threads**: the same pipeline with
//!     `2 × n_servers` pool threads vs the classic one-per-shard —
//!     the `elastic_threads_throughput` gate (≈1 on 1-core CI hosts,
//!     > 1 once cores exist to borrow).
//!  6. **Service-time-aware rebalancing** (DES): equal per-block push
//!     rates with a 9× slow-head service skew — the
//!     `service_time_vs_rate_rebalance` gate (virtual completion time,
//!     rate-only / cost-weighted planner; the cost model isolates the
//!     slow block, rate-only planning holds still).
//!
//!     cargo bench --bench placement_skew [-- --json]
//!     BENCH_QUICK=1 cargo bench --bench placement_skew -- --json

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use asybadmm::bench::{emit_hotpath_json, harness_from_env, json_requested, maybe_list_gates, BenchResult};
use asybadmm::config::{BlockSelection, Config, DrainKind, PlacementKind, TransportKind};
use asybadmm::coordinator::{
    load_imbalance, make_placement, make_transport, push_inflight, run_pool, run_server,
    BlockMap, BlockStore, BlockTable, ProxBackend, PushMsg, PushPool, Rebalancer, ServerShard,
    ShardRt, Topology,
};
use asybadmm::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec, WorkerShard};
use asybadmm::problem::Problem;
use asybadmm::sim::{run_sim, CostModel};

const N_BLOCKS: usize = 16;
const DB: usize = 256;
const N_SERVERS: usize = 4;
const N_WORKERS: usize = 4;

fn zipf_shards() -> Vec<WorkerShard> {
    let spec = SynthSpec {
        samples: 64,
        geometry: BlockGeometry::new(N_BLOCKS, DB),
        nnz_per_row: 8,
        blocks_per_worker: 8,
        // Hot head: 4 low-index blocks shared by every worker.
        shared_blocks: 4,
        ..Default::default()
    };
    gen_partitioned(&spec, N_WORKERS).1
}

struct PipelineResult {
    rate: f64,
    /// Applied pushes per shard (lane attribution).
    per_shard: Vec<usize>,
    migrations: usize,
}

/// End-to-end enqueue-to-apply pipeline: producers route by the live
/// block→shard map (static for the static placements; rebalanced at
/// runtime when `rebalance` is set) and stamp per-(worker, block)
/// sequence numbers; `n_threads` server threads drain under `drain`
/// (an elastic pool when `n_threads != N_SERVERS`), applying the real
/// Eq. 13 update per push.
fn drain_pipeline(
    shards: &[WorkerShard],
    placement: PlacementKind,
    drain: DrainKind,
    batch: usize,
    per_worker: usize,
    n_threads: usize,
    rebalance: bool,
) -> PipelineResult {
    let topo =
        Topology::build_with(shards, N_BLOCKS, N_SERVERS, make_placement(placement).as_ref());
    let store = Arc::new(BlockStore::new(N_BLOCKS, DB));
    let problem = Problem::new(LossKind::Logistic, 1e-5, 1e4);
    let table = Arc::new(BlockTable::new(&topo, store, problem, 4.0, 0.01));
    let map = Arc::new(BlockMap::new(&topo.server_of_block));
    let transport = make_transport(
        TransportKind::SpscRing,
        N_WORKERS,
        N_SERVERS,
        push_inflight(N_WORKERS),
        batch,
    );
    let rts: Vec<ShardRt> = (0..N_SERVERS)
        .map(|sid| {
            let shard = ServerShard::with_table(sid, &topo, table.clone(), !rebalance);
            ShardRt::new(shard, transport.as_ref())
        })
        .collect();
    let stop = AtomicBool::new(false);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let mut producers = Vec::new();
        for shard in shards {
            let w = shard.worker_id;
            let mut tx = transport.connect_worker(w);
            let map = &map;
            let active = &shard.active_blocks;
            producers.push(scope.spawn(move || {
                let mut pool = PushPool::new(DB, 64);
                let mut seqs = vec![0u64; N_BLOCKS];
                for i in 0..per_worker {
                    let j = active[i % active.len()];
                    seqs[j] += 1;
                    let msg = PushMsg {
                        worker: w,
                        block: j,
                        w: pool.acquire(),
                        worker_epoch: i,
                        z_version_used: 0,
                        block_seq: seqs[j],
                        sent_at: None,
                        recycle: Some(pool.recycler()),
                    };
                    tx.send(map.owner(j), msg).unwrap();
                }
                tx.flush().unwrap();
            }));
        }
        if rebalance {
            let mut rb = Rebalancer::new(map.clone(), table.clone(), N_SERVERS);
            let stop = &stop;
            scope.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    rb.scan();
                    std::thread::sleep(std::time::Duration::from_micros(500));
                }
            });
        }
        let rts_ref = &rts;
        for tid in 0..n_threads {
            scope.spawn(move || {
                if n_threads == N_SERVERS {
                    run_server(rts_ref, tid, drain, &ProxBackend::Native).unwrap();
                } else {
                    run_pool(rts_ref, tid, &ProxBackend::Native).unwrap();
                }
            });
        }
        for p in producers {
            p.join().unwrap();
        }
        transport.shutdown();
        stop.store(true, Ordering::Release);
    });
    let per_shard: Vec<usize> = rts.iter().map(|rt| rt.shard.stats().pushes).collect();
    let applied: usize = per_shard.iter().sum();
    assert_eq!(applied, N_WORKERS * per_worker, "pushes lost in the drain pipeline");
    PipelineResult {
        rate: applied as f64 / t0.elapsed().as_secs_f64(),
        per_shard,
        migrations: map.migrations(),
    }
}

/// Max/mean applied-push imbalance over the pipeline's shard counts.
fn push_imbalance(per_shard: &[usize]) -> f64 {
    let total: usize = per_shard.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let mean = total as f64 / per_shard.len() as f64;
    *per_shard.iter().max().unwrap() as f64 / mean
}

/// Record an externally-timed measurement (seconds per op) so it lands
/// in the harness's CSV/JSON alongside closure-timed benches.
fn record(h: &mut asybadmm::bench::Harness, name: &str, per_op_s: f64) {
    h.results.push(BenchResult {
        name: name.to_string(),
        samples: vec![per_op_s],
        mean_s: per_op_s,
        std_s: 0.0,
        p50_s: per_op_s,
        p95_s: per_op_s,
    });
}

fn main() {
    if maybe_list_gates() {
        return;
    }
    let quick = std::env::var("BENCH_QUICK").as_deref() == Ok("1");
    let mut h = harness_from_env();
    println!("== placement + drain + adaptive runtime under Zipf-hot blocks ==");

    let shards = zipf_shards();

    // 1. Static shard-load skew per placement.
    let base = Topology::build(&shards, N_BLOCKS, N_SERVERS);
    let degree: Vec<usize> = (0..N_BLOCKS).map(|j| base.degree_of_block(j)).collect();
    let imbalance = |kind: PlacementKind| -> f64 {
        let t = Topology::build_with(
            &shards,
            N_BLOCKS,
            N_SERVERS,
            make_placement(kind).as_ref(),
        );
        load_imbalance(&t.server_of_block, &degree, N_SERVERS)
    };
    let imb_contig = imbalance(PlacementKind::Contiguous);
    let imb_hash = imbalance(PlacementKind::Hash);
    let imb_degree = imbalance(PlacementKind::Degree);
    let skew_ratio = imb_contig / imb_degree.max(1e-12);
    println!(
        "shard load imbalance (max/mean; 1.0 = balanced):\n\
         \x20 contiguous {imb_contig:.3}\n\
         \x20 hash       {imb_hash:.3}\n\
         \x20 degree     {imb_degree:.3}\n\
         \x20 -> contiguous/degree = {skew_ratio:.2}x  (gate: > 1.0)"
    );

    // 2. Enqueue-to-apply throughput: the drain-policy comparison.
    let per_worker = if quick { 2_000 } else { 20_000 };
    // Warm (thread spawn, page faults).
    drain_pipeline(&shards, PlacementKind::Contiguous, DrainKind::Owned, 1, 500, N_SERVERS, false);
    let owned = drain_pipeline(
        &shards,
        PlacementKind::Contiguous,
        DrainKind::Owned,
        1,
        per_worker,
        N_SERVERS,
        false,
    );
    let steal = drain_pipeline(
        &shards,
        PlacementKind::Degree,
        DrainKind::Steal,
        1,
        per_worker,
        N_SERVERS,
        false,
    );
    let steal_ratio = steal.rate / owned.rate.max(1.0);
    record(&mut h, "contiguous+owned enqueue-to-apply", 1.0 / owned.rate.max(1.0));
    record(&mut h, "degree+steal enqueue-to-apply", 1.0 / steal.rate.max(1.0));
    println!(
        "\nenqueue-to-apply ({N_WORKERS} workers -> {N_SERVERS} shards, db={DB}):\n\
         \x20 contiguous+owned {:>10.0} pushes/s\n\
         \x20 degree+steal     {:>10.0} pushes/s\n\
         \x20 -> degree+steal / contiguous+owned = {steal_ratio:.2}x \
         (gate; <1 expected only on 1-core hosts)",
        owned.rate, steal.rate
    );

    // 3. Batched ring slots at the same shape.
    let batch1 = drain_pipeline(
        &shards,
        PlacementKind::Degree,
        DrainKind::Owned,
        1,
        per_worker,
        N_SERVERS,
        false,
    );
    let batch8 = drain_pipeline(
        &shards,
        PlacementKind::Degree,
        DrainKind::Owned,
        8,
        per_worker,
        N_SERVERS,
        false,
    );
    let batch_ratio = batch8.rate / batch1.rate.max(1.0);
    record(&mut h, "ring batch=1 enqueue-to-apply", 1.0 / batch1.rate.max(1.0));
    record(&mut h, "ring batch=8 enqueue-to-apply", 1.0 / batch8.rate.max(1.0));
    println!(
        "\nbatched ring slots (degree+owned):\n\
         \x20 batch=1 {:>10.0} pushes/s\n\
         \x20 batch=8 {:>10.0} pushes/s\n\
         \x20 -> batch amortization = {batch_ratio:.2}x",
        batch1.rate, batch8.rate
    );

    // 4. Dynamic re-placement: contiguous start + runtime rebalancer vs
    //    the static maps, scored on APPLIED-push imbalance.
    let dynamic = drain_pipeline(
        &shards,
        PlacementKind::Dynamic,
        DrainKind::Owned,
        1,
        per_worker,
        N_SERVERS,
        true,
    );
    let contig_push_imb = push_imbalance(&owned.per_shard);
    let degree_push_imb = push_imbalance(&batch1.per_shard);
    let dynamic_push_imb = push_imbalance(&dynamic.per_shard);
    let dyn_vs_degree = dynamic_push_imb / degree_push_imb.max(1e-12);
    record(&mut h, "dynamic enqueue-to-apply", 1.0 / dynamic.rate.max(1.0));
    println!(
        "\ndynamic re-placement (contiguous start, rebalancer live, {} migrations):\n\
         \x20 applied-push imbalance contiguous {contig_push_imb:.3} | degree \
         {degree_push_imb:.3} | dynamic {dynamic_push_imb:.3}\n\
         \x20 -> dynamic/degree = {dyn_vs_degree:.2}x  (gate: <= ~1, \
         and dynamic must beat contiguous)",
        dynamic.migrations
    );

    // 5. Elastic server threads: 2x pool vs one-per-shard.
    let elastic = drain_pipeline(
        &shards,
        PlacementKind::Degree,
        DrainKind::Owned,
        1,
        per_worker,
        2 * N_SERVERS,
        false,
    );
    let elastic_ratio = elastic.rate / batch1.rate.max(1.0);
    record(&mut h, "elastic 2x-threads enqueue-to-apply", 1.0 / elastic.rate.max(1.0));
    println!(
        "\nelastic server threads (degree+pool):\n\
         \x20 threads={}  {:>10.0} pushes/s\n\
         \x20 threads={} {:>10.0} pushes/s\n\
         \x20 -> elastic throughput = {elastic_ratio:.2}x (≈1 on 1-core hosts)",
        N_SERVERS,
        batch1.rate,
        2 * N_SERVERS,
        elastic.rate
    );

    // 6. Service-time-aware rebalancing (DES): a slow-head service skew
    //    that rate-only planning cannot see.  Every worker cycles over
    //    every block, so per-block push RATES are equal — but block 0's
    //    Eq. 13 service costs 9× the rest, queueing its shard.  The
    //    cost-weighted planner (rate × per-block service EWMA, the
    //    threaded Rebalancer's weight since this PR) isolates the slow
    //    block; the legacy rate-only weight sees balance and holds
    //    still.  Gate: virtual completion time rate-only /
    //    cost-weighted (> 1 once the skew binds).
    let sim_arm = |weighted: bool| {
        let mut cfg = Config::tiny_test();
        cfg.epochs = if quick { 200 } else { 400 };
        cfg.n_workers = 4;
        cfg.n_blocks = 4;
        cfg.blocks_per_worker = 4;
        cfg.shared_blocks = 4;
        cfg.placement = PlacementKind::Dynamic;
        cfg.selection = BlockSelection::Cyclic;
        cfg.rebalance_ms = 20;
        cfg.log_every = 100_000;
        let cost = CostModel {
            compute_fixed_s: 1e-4,
            compute_per_row_s: 0.0,
            server_service_s: 5e-5,
            net_mean_s: 0.0,
            slow_head_blocks: 1,
            slow_head_factor: 9.0,
            cost_weighted_rebalance: weighted,
            ..CostModel::default()
        };
        let (ds, sim_shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
        run_sim(&cfg, &ds, &sim_shards, &cost).unwrap()
    };
    let r_cost = sim_arm(true);
    let r_rate = sim_arm(false);
    let svc_ratio = r_rate.virtual_time_s / r_cost.virtual_time_s.max(1e-12);
    record(&mut h, "DES rate-only rebalance (slow head)", r_rate.virtual_time_s);
    record(&mut h, "DES cost-weighted rebalance (slow head)", r_cost.virtual_time_s);
    println!(
        "\nservice-time-aware rebalancing (DES, slow head 9x, equal rates):\n\
         \x20 rate-only     {:.4}s virtual, {} migrations, final map {:?}\n\
         \x20 cost-weighted {:.4}s virtual, {} migrations, final map {:?}\n\
         \x20 -> rate-only / cost-weighted = {svc_ratio:.2}x  (gate: >= ~1)",
        r_rate.virtual_time_s,
        r_rate.migrations,
        r_rate.placement_final,
        r_cost.virtual_time_s,
        r_cost.migrations,
        r_cost.placement_final
    );

    println!("\n{}", h.csv());

    if json_requested() {
        emit_hotpath_json(
            "placement_skew",
            &h,
            &[
                ("contiguous_imbalance", imb_contig),
                ("hash_imbalance", imb_hash),
                ("degree_imbalance", imb_degree),
                ("degree_vs_contiguous_skew", skew_ratio),
                ("owned_drain_push_per_s", owned.rate),
                ("steal_drain_push_per_s", steal.rate),
                ("steal_vs_owned_drain", steal_ratio),
                ("ring_batch_amortization", batch_ratio),
                ("contiguous_push_imbalance", contig_push_imb),
                ("degree_push_imbalance", degree_push_imb),
                ("dynamic_push_imbalance", dynamic_push_imb),
                ("dynamic_vs_degree_skew", dyn_vs_degree),
                ("dynamic_migrations", dynamic.migrations as f64),
                ("elastic_threads_throughput", elastic_ratio),
                ("service_time_vs_rate_rebalance", svc_ratio),
            ],
        );
    }
}

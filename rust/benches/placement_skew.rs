//! Placement + drain-policy scaling under a Zipf-skewed block workload.
//!
//! The synthetic workload's hot shared blocks have low indices, so the
//! default contiguous placement concentrates the whole Zipf head on
//! shard 0 — the server-side serialization this PR's placement/drain
//! layer exists to break.  Three measurements:
//!
//!  1. **Static skew**: max/mean shard load (load = Σ |𝒩(j)| over owned
//!     blocks) under contiguous vs hash vs degree placement — the
//!     `degree_vs_contiguous_skew` gate (how much better the
//!     degree-aware packing balances the hot head).
//!  2. **Enqueue-to-apply throughput**: workers blast pooled pushes
//!     routed by the placement while server threads drain under
//!     `owned` vs `steal` — the `steal_vs_owned_drain` gate
//!     (`placement=degree drain=steal` vs `placement=contiguous
//!     drain=owned`; on a 1-core host expect ≈1, on multi-core > 1).
//!  3. **Batched ring slots**: the same pipeline at `batch=8` vs
//!     `batch=1` (`ring_batch_amortization`) — per-slot atomics
//!     amortized over whole w-block batches.
//!
//!     cargo bench --bench placement_skew [-- --json]
//!     BENCH_QUICK=1 cargo bench --bench placement_skew -- --json

use std::sync::Arc;
use std::time::Instant;

use asybadmm::bench::{emit_hotpath_json, harness_from_env, json_requested, BenchResult};
use asybadmm::config::{DrainKind, PlacementKind, TransportKind};
use asybadmm::coordinator::{
    load_imbalance, make_placement, make_transport, push_inflight, run_server, BlockStore,
    ProxBackend, PushMsg, PushPool, ServerShard, ShardRt, Topology,
};
use asybadmm::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec, WorkerShard};
use asybadmm::problem::Problem;

const N_BLOCKS: usize = 16;
const DB: usize = 256;
const N_SERVERS: usize = 4;
const N_WORKERS: usize = 4;

fn zipf_shards() -> Vec<WorkerShard> {
    let spec = SynthSpec {
        samples: 64,
        geometry: BlockGeometry::new(N_BLOCKS, DB),
        nnz_per_row: 8,
        blocks_per_worker: 8,
        // Hot head: 4 low-index blocks shared by every worker.
        shared_blocks: 4,
        ..Default::default()
    };
    gen_partitioned(&spec, N_WORKERS).1
}

/// End-to-end enqueue-to-apply throughput (pushes/s): producers route
/// by the placement's block→shard map; server threads drain under
/// `drain`, applying the real Eq. 13 update per push.
fn drain_throughput(
    shards: &[WorkerShard],
    placement: PlacementKind,
    drain: DrainKind,
    batch: usize,
    per_worker: usize,
) -> f64 {
    let topo =
        Topology::build_with(shards, N_BLOCKS, N_SERVERS, make_placement(placement).as_ref());
    let store = Arc::new(BlockStore::new(N_BLOCKS, DB));
    let problem = Problem::new(LossKind::Logistic, 1e-5, 1e4);
    let transport = make_transport(
        TransportKind::SpscRing,
        N_WORKERS,
        N_SERVERS,
        push_inflight(N_WORKERS),
        batch,
    );
    let rts: Vec<ShardRt> = (0..N_SERVERS)
        .map(|sid| {
            let shard = ServerShard::new(sid, &topo, store.clone(), problem, 4.0, 0.01);
            ShardRt::new(shard, transport.as_ref())
        })
        .collect();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let mut producers = Vec::new();
        for shard in shards {
            let w = shard.worker_id;
            let mut tx = transport.connect_worker(w);
            let topo = &topo;
            let active = &shard.active_blocks;
            producers.push(scope.spawn(move || {
                let mut pool = PushPool::new(DB, 64);
                for i in 0..per_worker {
                    let j = active[i % active.len()];
                    let msg = PushMsg {
                        worker: w,
                        block: j,
                        w: pool.acquire(),
                        worker_epoch: i,
                        z_version_used: 0,
                        sent_at: Instant::now(),
                        recycle: Some(pool.recycler()),
                    };
                    tx.send(topo.server_of_block[j], msg).unwrap();
                }
                tx.flush().unwrap();
            }));
        }
        let rts_ref = &rts;
        for sid in 0..N_SERVERS {
            scope.spawn(move || {
                run_server(rts_ref, sid, drain, &ProxBackend::Native).unwrap();
            });
        }
        for p in producers {
            p.join().unwrap();
        }
        transport.shutdown();
    });
    let applied: usize = rts.iter().map(|rt| rt.shard.stats().pushes).sum();
    assert_eq!(applied, N_WORKERS * per_worker, "pushes lost in the drain pipeline");
    applied as f64 / t0.elapsed().as_secs_f64()
}

/// Record an externally-timed measurement (seconds per op) so it lands
/// in the harness's CSV/JSON alongside closure-timed benches.
fn record(h: &mut asybadmm::bench::Harness, name: &str, per_op_s: f64) {
    h.results.push(BenchResult {
        name: name.to_string(),
        samples: vec![per_op_s],
        mean_s: per_op_s,
        std_s: 0.0,
        p50_s: per_op_s,
        p95_s: per_op_s,
    });
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").as_deref() == Ok("1");
    let mut h = harness_from_env();
    println!("== placement + drain policy under Zipf-hot blocks ==");

    let shards = zipf_shards();

    // 1. Static shard-load skew per placement.
    let base = Topology::build(&shards, N_BLOCKS, N_SERVERS);
    let degree: Vec<usize> = (0..N_BLOCKS).map(|j| base.degree_of_block(j)).collect();
    let imbalance = |kind: PlacementKind| -> f64 {
        let t = Topology::build_with(
            &shards,
            N_BLOCKS,
            N_SERVERS,
            make_placement(kind).as_ref(),
        );
        load_imbalance(&t.server_of_block, &degree, N_SERVERS)
    };
    let imb_contig = imbalance(PlacementKind::Contiguous);
    let imb_hash = imbalance(PlacementKind::Hash);
    let imb_degree = imbalance(PlacementKind::Degree);
    let skew_ratio = imb_contig / imb_degree.max(1e-12);
    println!(
        "shard load imbalance (max/mean; 1.0 = balanced):\n\
         \x20 contiguous {imb_contig:.3}\n\
         \x20 hash       {imb_hash:.3}\n\
         \x20 degree     {imb_degree:.3}\n\
         \x20 -> contiguous/degree = {skew_ratio:.2}x  (gate: > 1.0)"
    );

    // 2. Enqueue-to-apply throughput: the ISSUE's headline comparison.
    let per_worker = if quick { 2_000 } else { 20_000 };
    // Warm (thread spawn, page faults).
    drain_throughput(&shards, PlacementKind::Contiguous, DrainKind::Owned, 1, 500);
    let owned_rate =
        drain_throughput(&shards, PlacementKind::Contiguous, DrainKind::Owned, 1, per_worker);
    let steal_rate =
        drain_throughput(&shards, PlacementKind::Degree, DrainKind::Steal, 1, per_worker);
    let steal_ratio = steal_rate / owned_rate.max(1.0);
    record(&mut h, "contiguous+owned enqueue-to-apply", 1.0 / owned_rate.max(1.0));
    record(&mut h, "degree+steal enqueue-to-apply", 1.0 / steal_rate.max(1.0));
    println!(
        "\nenqueue-to-apply ({N_WORKERS} workers -> {N_SERVERS} shards, db={DB}):\n\
         \x20 contiguous+owned {owned_rate:>10.0} pushes/s\n\
         \x20 degree+steal     {steal_rate:>10.0} pushes/s\n\
         \x20 -> degree+steal / contiguous+owned = {steal_ratio:.2}x \
         (gate; <1 expected only on 1-core hosts)"
    );

    // 3. Batched ring slots at the same shape.
    let batch1 =
        drain_throughput(&shards, PlacementKind::Degree, DrainKind::Owned, 1, per_worker);
    let batch8 =
        drain_throughput(&shards, PlacementKind::Degree, DrainKind::Owned, 8, per_worker);
    let batch_ratio = batch8 / batch1.max(1.0);
    record(&mut h, "ring batch=1 enqueue-to-apply", 1.0 / batch1.max(1.0));
    record(&mut h, "ring batch=8 enqueue-to-apply", 1.0 / batch8.max(1.0));
    println!(
        "\nbatched ring slots (degree+owned):\n\
         \x20 batch=1 {batch1:>10.0} pushes/s\n\
         \x20 batch=8 {batch8:>10.0} pushes/s\n\
         \x20 -> batch amortization = {batch_ratio:.2}x"
    );

    println!("\n{}", h.csv());

    if json_requested() {
        emit_hotpath_json(
            "placement_skew",
            &h,
            &[
                ("contiguous_imbalance", imb_contig),
                ("hash_imbalance", imb_hash),
                ("degree_imbalance", imb_degree),
                ("degree_vs_contiguous_skew", skew_ratio),
                ("owned_drain_push_per_s", owned_rate),
                ("steal_drain_push_per_s", steal_rate),
                ("steal_vs_owned_drain", steal_ratio),
                ("ring_batch_amortization", batch_ratio),
            ],
        );
    }
}

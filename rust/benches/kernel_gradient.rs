//! L1 kernel bench: the fused margin + block-gradient hot-spot — the
//! precomputed block-slice index vs the per-row `partition_point` scan,
//! native CSR across scales, and (when artifacts exist) the AOT XLA
//! artifact (grad_chunk / fused worker_step).
//!
//!     cargo bench --bench kernel_gradient [-- --json]
//!     BENCH_QUICK=1 cargo bench --bench kernel_gradient

use std::path::Path;

use asybadmm::admm::NativeEngine;
use asybadmm::bench::{emit_hotpath_json, harness_from_env, json_requested, maybe_list_gates};
use asybadmm::config::KernelKind;
use asybadmm::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};
use asybadmm::problem::Problem;
use asybadmm::runtime::{Manifest, WorkerXla, XlaEngine};
use asybadmm::sparse::Kernels;
use asybadmm::util::rng::Rng;

fn main() {
    if maybe_list_gates() {
        return;
    }
    let mut h = harness_from_env();
    println!("== L1 gradient kernel (lower is better) ==");

    // --- block-sliced index vs partition_point scan -----------------------
    // Shards where one block covers 1/blocks of the packed columns; the
    // sliced kernel must win whenever that share is <= 25%.
    let mut slice_speedups: Vec<f64> = Vec::new();
    let slice_cases = [(2048usize, 8usize, 64usize, 16usize), (2048, 16, 64, 32), (512, 4, 256, 24)];
    for (m, blocks, db, nnz) in slice_cases {
        let spec = SynthSpec {
            samples: m,
            geometry: BlockGeometry::new(blocks, db),
            nnz_per_row: nnz,
            blocks_per_worker: blocks,
            shared_blocks: 1,
            ..Default::default()
        };
        let (_, shards) = gen_partitioned(&spec, 1);
        let shard = &shards[0];
        let a = &shard.a_packed;
        let mut rng = Rng::new(17);
        let s: Vec<f32> = (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut g = vec![0.0f32; db];
        let slot = blocks / 2; // interior block: worst case for the scan
        let (lo, hi) = shard.slot_range(slot);
        let share = 100.0 / blocks as f64;
        let scan = h
            .bench(&format!("scan  block-grad d={} db={db} ({share:.0}% cols)", blocks * db), || {
                g.fill(0.0);
                a.tmatvec_block_acc(&s, lo, hi, &mut g);
            })
            .mean_s;
        let sliced = h
            .bench(&format!("slice block-grad d={} db={db} ({share:.0}% cols)", blocks * db), || {
                g.fill(0.0);
                a.tmatvec_block_sliced(&s, &shard.slices, slot, &mut g);
            })
            .mean_s;
        let speedup = scan / sliced.max(1e-12);
        slice_speedups.push(speedup);
        println!(
            "  -> sliced {speedup:.2}x vs scan ({:.1} vs {:.1} Mnnz-in-block/s)",
            shard.slices.block_nnz(slot) as f64 / sliced / 1e6,
            shard.slices.block_nnz(slot) as f64 / scan / 1e6,
        );
    }

    // --- fused native grad_block across scales ----------------------------
    for (m, blocks, db, nnz) in [(256usize, 8usize, 64usize, 16usize), (2048, 8, 512, 40)] {
        let spec = SynthSpec {
            samples: m,
            geometry: BlockGeometry::new(blocks, db),
            nnz_per_row: nnz,
            blocks_per_worker: blocks,
            shared_blocks: 1,
            ..Default::default()
        };
        let (ds, shards) = gen_partitioned(&spec, 1);
        let shard = &shards[0];
        let problem = Problem::new(LossKind::Logistic, 1e-5, 1e4);
        let mut eng = NativeEngine::new(shard, problem, 1.0 / ds.samples() as f32);
        let z = vec![0.01f32; shard.packed_dim()];
        let mut g = vec![0.0f32; db];
        let r = h.bench(&format!("native grad_block m={m} d={} db={db}", blocks * db), || {
            eng.grad_block(&z, 0, &mut g);
        });
        println!("  -> {:.1} Mrows/s, {:.1} Mnnz/s",
            m as f64 / r.mean_s / 1e6,
            ds.a.nnz() as f64 / r.mean_s / 1e6);
    }

    // --- runtime SIMD dispatch: SpMV (margins matvec) simd vs unrolled ----
    // The `kernel=simd` table is gated bit-identical to `unrolled` in
    // sparse::simd's tests; here we record what the AVX2 gathers buy on
    // this host.  On a non-AVX2 host `simd` resolves to `unrolled`
    // (Kernels::name says so) and the gate records a neutral 1.0.
    let mut simd_vs_unrolled = 1.0;
    {
        let spec = SynthSpec {
            samples: 2048,
            geometry: BlockGeometry::new(8, 512),
            nnz_per_row: 40,
            blocks_per_worker: 8,
            shared_blocks: 1,
            ..Default::default()
        };
        let (_, shards) = gen_partitioned(&spec, 1);
        let shard = &shards[0];
        let a = &shard.a_packed;
        let mut rng = Rng::new(0x51D);
        let x: Vec<f32> =
            (0..shard.packed_dim()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut out = vec![0.0f32; 2048];
        let unrolled = Kernels::select(KernelKind::Unrolled);
        let simd = Kernels::select(KernelKind::Simd);
        let ru = h
            .bench("unrolled matvec m=2048 d_pad=4096", || {
                (unrolled.matvec)(a, &x, &mut out);
            })
            .mean_s;
        if simd.name == "simd" {
            let rs = h
                .bench("simd     matvec m=2048 d_pad=4096", || {
                    (simd.matvec)(a, &x, &mut out);
                })
                .mean_s;
            simd_vs_unrolled = ru / rs.max(1e-12);
            println!("  -> simd {simd_vs_unrolled:.2}x vs unrolled (AVX2 gathers)");
        } else {
            println!(
                "  (no AVX2 at runtime: kernel=simd resolves to '{}'; \
                 simd_vs_unrolled_spmv = 1.0)",
                simd.name
            );
        }
    }

    // --- XLA artifacts (requires `make artifacts`) ------------------------
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Err(_) => println!("(skipping XLA benches: run `make artifacts`)"),
        Ok(manifest) => {
            for (mc, dp, db, m, blocks, nnz) in [
                (256usize, 512usize, 64usize, 256usize, 8usize, 16usize),
                (2048, 4096, 512, 2048, 8, 40),
            ] {
                let spec = SynthSpec {
                    samples: m,
                    geometry: BlockGeometry::new(blocks, db),
                    nnz_per_row: nnz,
                    blocks_per_worker: blocks,
                    shared_blocks: 1,
                    ..Default::default()
                };
                let (ds, shards) = gen_partitioned(&spec, 1);
                let shard = &shards[0];
                let Ok(engine) = XlaEngine::new(&manifest, "logistic", mc, dp, db) else {
                    println!("(no artifacts for m_chunk={mc}; skipping)");
                    continue;
                };
                let mut xla = WorkerXla::new(engine, shard, 1.0 / ds.samples() as f32).unwrap();
                let z = vec![0.01f32; shard.packed_dim()];
                let y = vec![0.0f32; db];
                let r = h.bench(&format!("xla   worker_step m={m} d_pad={dp} db={db}"), || {
                    xla.step(&z, &y, 0, 4.0).unwrap();
                });
                // Dense MACs the artifact executes: margins (m*dp) + block
                // grad (m*db) per chunk.
                let macs = (m * dp + m * db) as f64;
                println!("  -> {:.2} GMAC/s dense-equivalent", macs / r.mean_s / 1e9);
            }
        }
    }
    println!("\n{}", h.csv());

    if json_requested() {
        let min_speedup = slice_speedups.iter().cloned().fold(f64::INFINITY, f64::min);
        emit_hotpath_json(
            "kernel_gradient",
            &h,
            &[
                ("sliced_vs_scan_min_speedup", min_speedup),
                ("simd_vs_unrolled_spmv", simd_vs_unrolled),
            ],
        );
    }
}

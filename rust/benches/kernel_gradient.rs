//! L1 kernel bench: the fused margin + block-gradient hot-spot, native
//! CSR vs the AOT XLA artifact (grad_chunk / fused worker_step).
//!
//!     cargo bench --bench kernel_gradient        # full
//!     BENCH_QUICK=1 cargo bench --bench kernel_gradient

use std::path::Path;

use asybadmm::admm::NativeEngine;
use asybadmm::bench::harness_from_env;
use asybadmm::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};
use asybadmm::problem::Problem;
use asybadmm::runtime::{Manifest, WorkerXla, XlaEngine};

fn main() {
    let mut h = harness_from_env();
    println!("== L1 gradient kernel (lower is better) ==");

    // --- native across scales -------------------------------------------
    for (m, blocks, db, nnz) in [(256usize, 8usize, 64usize, 16usize), (2048, 8, 512, 40)] {
        let spec = SynthSpec {
            samples: m,
            geometry: BlockGeometry::new(blocks, db),
            nnz_per_row: nnz,
            blocks_per_worker: blocks,
            shared_blocks: 1,
            ..Default::default()
        };
        let (ds, shards) = gen_partitioned(&spec, 1);
        let shard = &shards[0];
        let problem = Problem::new(LossKind::Logistic, 1e-5, 1e4);
        let mut eng = NativeEngine::new(shard, problem, 1.0 / ds.samples() as f32);
        let z = vec![0.01f32; shard.packed_dim()];
        let mut g = vec![0.0f32; db];
        let r = h.bench(&format!("native grad_block m={m} d={} db={db}", blocks * db), || {
            eng.grad_block(&z, 0, &mut g);
        });
        println!("  -> {:.1} Mrows/s, {:.1} Mnnz/s",
            m as f64 / r.mean_s / 1e6,
            ds.a.nnz() as f64 / r.mean_s / 1e6);
    }

    // --- XLA artifacts (requires `make artifacts`) ------------------------
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("(skipping XLA benches: run `make artifacts`)");
        return;
    };
    for (mc, dp, db, m, blocks, nnz) in
        [(256usize, 512usize, 64usize, 256usize, 8usize, 16usize), (2048, 4096, 512, 2048, 8, 40)]
    {
        let spec = SynthSpec {
            samples: m,
            geometry: BlockGeometry::new(blocks, db),
            nnz_per_row: nnz,
            blocks_per_worker: blocks,
            shared_blocks: 1,
            ..Default::default()
        };
        let (ds, shards) = gen_partitioned(&spec, 1);
        let shard = &shards[0];
        let Ok(engine) = XlaEngine::new(&manifest, "logistic", mc, dp, db) else {
            println!("(no artifacts for m_chunk={mc}; skipping)");
            continue;
        };
        let mut xla = WorkerXla::new(engine, shard, 1.0 / ds.samples() as f32).unwrap();
        let z = vec![0.01f32; shard.packed_dim()];
        let y = vec![0.0f32; db];
        let r = h.bench(&format!("xla   worker_step m={m} d_pad={dp} db={db}"), || {
            xla.step(&z, &y, 0, 4.0).unwrap();
        });
        // Dense MACs the artifact executes: margins (m*dp) + block grad
        // (m*db) per chunk.
        let macs = (m * dp + m * db) as f64;
        println!("  -> {:.2} GMAC/s dense-equivalent", macs / r.mean_s / 1e9);
    }
    println!("\n{}", h.csv());
}

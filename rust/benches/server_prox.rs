//! Server-side Eq. 13 prox update throughput: native vs XLA artifact,
//! plus the incremental w̃-sum bookkeeping — i.e. the entire per-push
//! server service time that bounds coordinator scalability.  The push
//! message is built once and reused: with the pooled-buffer protocol the
//! steady-state handle path allocates nothing, and the bench measures
//! exactly that path.
//!
//!     cargo bench --bench server_prox [-- --json]

use std::path::Path;
use std::sync::Arc;

use asybadmm::admm::prox_l1_box;
use asybadmm::bench::{emit_hotpath_json, harness_from_env, json_requested};
use asybadmm::coordinator::{BlockStore, PushMsg, ServerShard, Topology};
use asybadmm::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};
use asybadmm::problem::Problem;
use asybadmm::runtime::{Manifest, ServerProxXla};

fn main() {
    let mut h = harness_from_env();
    println!("== server prox / push service (lower is better) ==");

    for db in [64usize, 512] {
        let zt = vec![0.1f32; db];
        let ws = vec![0.2f32; db];
        let mut out = vec![0.0f32; db];
        let r = h.bench(&format!("native prox_l1_box db={db}"), || {
            prox_l1_box(&zt, &ws, 0.01, 16.0, 1e-5, 1e4, &mut out);
        });
        println!("  -> {:.1} Melem/s", db as f64 / r.mean_s / 1e6);
    }

    // Full push handling (w̃ bookkeeping + prox + seqlock store publish).
    let spec = SynthSpec {
        samples: 64,
        geometry: BlockGeometry::new(8, 64),
        nnz_per_row: 8,
        blocks_per_worker: 8,
        shared_blocks: 1,
        ..Default::default()
    };
    let (_, shards) = gen_partitioned(&spec, 4);
    let topo = Topology::build(&shards, 8, 1);
    let store = Arc::new(BlockStore::new(8, 64));
    let problem = Problem::new(LossKind::Logistic, 1e-5, 1e4);
    let mut srv = ServerShard::new(0, &topo, store, problem, 4.0, 0.01);
    let block = srv.owned_blocks()[0];
    let worker = topo.workers_of_block[block][0];
    let msg = PushMsg {
        worker,
        block,
        w: vec![0.3f32; 64],
        worker_epoch: 0,
        z_version_used: 0,
        sent_at: std::time::Instant::now(),
        recycle: None,
    };
    h.bench("server handle_push (native, db=64)", || {
        srv.handle_push(&msg, &asybadmm::coordinator::ProxBackend::Native).unwrap();
    });

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Err(_) => println!("(skipping XLA prox: run `make artifacts`)"),
        Ok(m) => {
            for db in [64usize, 512] {
                let Ok(sp) = ServerProxXla::load(&m, db) else { continue };
                let zt = vec![0.1f32; db];
                let ws = vec![0.2f32; db];
                let r = h.bench(&format!("xla    server_prox db={db}"), || {
                    sp.prox(&zt, &ws, 0.01, 16.0, 1e-5, 1e4).unwrap();
                });
                println!("  -> {:.1} Melem/s (incl. PJRT dispatch)", db as f64 / r.mean_s / 1e6);
            }
        }
    }
    println!("\n{}", h.csv());

    if json_requested() {
        emit_hotpath_json("server_prox", &h, &[]);
    }
}

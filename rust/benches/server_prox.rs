//! Server-side Eq. 13 prox update throughput: native vs XLA artifact,
//! plus the incremental w̃-sum bookkeeping — i.e. the entire per-push
//! server service time that bounds coordinator scalability.  The push
//! message is built once and reused: with the pooled-buffer protocol the
//! steady-state handle path allocates nothing, and the bench measures
//! exactly that path.
//!
//! The 4-wide unrolled prox / w̃-sum paths (ROADMAP "SIMD prox") are
//! **gated bit-identical** against their scalar references here: the
//! bench asserts exact `to_bits` equality over randomized inputs before
//! timing, then records the unrolled-vs-scalar speedups
//! (`prox_unrolled_vs_scalar`, `wsum_unrolled_vs_scalar`) in
//! BENCH_hotpath.json.
//!
//!     cargo bench --bench server_prox [-- --json]

use std::path::Path;
use std::sync::Arc;

use asybadmm::admm::{add_assign_diff, add_assign_diff_scalar, prox_l1_box, prox_l1_box_scalar};
use asybadmm::bench::{emit_hotpath_json, harness_from_env, json_requested, maybe_list_gates};
use asybadmm::config::KernelKind;
use asybadmm::coordinator::{BlockStore, PushMsg, ServerShard, Topology};
use asybadmm::data::{gen_partitioned, BlockGeometry, LossKind, SynthSpec};
use asybadmm::problem::Problem;
use asybadmm::runtime::{Manifest, ServerProxXla};
use asybadmm::sparse::Kernels;
use asybadmm::util::rng::Rng;

/// Bit-identity gate: the fast kernels (`prox`, `wsum`) must compute
/// the exact same f32 expression per element as the scalar references —
/// not just agree approximately.  Panics on the first divergent bit
/// pattern.
fn assert_bit_identical(
    tag: &str,
    db: usize,
    prox: fn(&[f32], &[f32], f32, f32, f32, f32, &mut [f32]),
    wsum: fn(&mut [f32], &[f32], &[f32]),
) {
    let mut rng = Rng::new(0xB17);
    for rep in 0..50 {
        let zt: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 3.0)).collect();
        let ws: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 3.0)).collect();
        let (gamma, denom) = (rng.f32(), 0.1 + rng.f32() * 20.0);
        let (lambda, clip) = (rng.f32(), 0.5 + rng.f32() * 4.0);
        let mut fast = vec![0.0f32; db];
        let mut slow = vec![0.0f32; db];
        prox(&zt, &ws, gamma, denom, lambda, clip, &mut fast);
        prox_l1_box_scalar(&zt, &ws, gamma, denom, lambda, clip, &mut slow);
        for (k, (a, b)) in fast.iter().zip(&slow).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{tag} prox diverged from scalar at rep {rep} elem {k}: {a} vs {b}"
            );
        }
        let base: Vec<f32> = (0..db).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let (mut s_fast, mut s_slow) = (base.clone(), base);
        wsum(&mut s_fast, &zt, &ws);
        add_assign_diff_scalar(&mut s_slow, &zt, &ws);
        for (k, (a, b)) in s_fast.iter().zip(&s_slow).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{tag} w-sum diverged from scalar at rep {rep} elem {k}: {a} vs {b}"
            );
        }
    }
}

fn main() {
    if maybe_list_gates() {
        return;
    }
    let mut h = harness_from_env();
    println!("== server prox / push service (lower is better) ==");

    let simd = Kernels::select(KernelKind::Simd);
    for db in [64usize, 512, 257] {
        // 257: odd length, remainder lanes covered.
        assert_bit_identical("unrolled", db, prox_l1_box, add_assign_diff);
        // The runtime-dispatched table (AVX2 when available, else the
        // unrolled fallback) is held to the same exact-bits standard.
        assert_bit_identical(simd.name, db, simd.prox_l1_box, simd.add_assign_diff);
    }
    println!(
        "bit-identity gate: unrolled + dispatched ('{}') prox / w-sum == scalar (PASS)",
        simd.name
    );

    let mut prox_ratio = 1.0;
    let mut wsum_ratio = 1.0;
    for db in [64usize, 512] {
        let zt = vec![0.1f32; db];
        let ws = vec![0.2f32; db];
        let mut out = vec![0.0f32; db];
        let r = h.bench(&format!("native prox_l1_box db={db}"), || {
            prox_l1_box(&zt, &ws, 0.01, 16.0, 1e-5, 1e4, &mut out);
        });
        println!("  -> {:.1} Melem/s", db as f64 / r.mean_s / 1e6);
        let unrolled_s = r.mean_s;
        let r = h.bench(&format!("scalar prox_l1_box db={db}"), || {
            prox_l1_box_scalar(&zt, &ws, 0.01, 16.0, 1e-5, 1e4, &mut out);
        });
        if db == 512 {
            prox_ratio = r.mean_s / unrolled_s.max(1e-12);
        }

        let mut sum = vec![0.3f32; db];
        let r = h.bench(&format!("unrolled w-sum update db={db}"), || {
            add_assign_diff(&mut sum, &zt, &ws);
        });
        let unrolled_s = r.mean_s;
        let r = h.bench(&format!("scalar   w-sum update db={db}"), || {
            add_assign_diff_scalar(&mut sum, &zt, &ws);
        });
        if db == 512 {
            wsum_ratio = r.mean_s / unrolled_s.max(1e-12);
        }
    }
    println!(
        "unrolled speedup at db=512: prox {prox_ratio:.2}x, w-sum {wsum_ratio:.2}x \
         (>= 1.0 expected; exact gain is ISA/LLVM dependent)"
    );

    // Runtime-dispatched (kernel=simd) prox vs the scalar reference at
    // db=512.  On a non-AVX2 host the table resolves to `unrolled`, so
    // the gate degrades to the unrolled ratio instead of going silent.
    let simd_prox_ratio = {
        let db = 512usize;
        let zt = vec![0.1f32; db];
        let ws = vec![0.2f32; db];
        let mut out = vec![0.0f32; db];
        let r = h.bench(&format!("{} prox_l1_box db={db} (dispatch)", simd.name), || {
            (simd.prox_l1_box)(&zt, &ws, 0.01, 16.0, 1e-5, 1e4, &mut out);
        });
        let fast_s = r.mean_s;
        let r = h.bench(&format!("scalar prox_l1_box db={db} (ref)"), || {
            prox_l1_box_scalar(&zt, &ws, 0.01, 16.0, 1e-5, 1e4, &mut out);
        });
        r.mean_s / fast_s.max(1e-12)
    };
    println!(
        "dispatched ('{}') prox speedup vs scalar at db=512: {simd_prox_ratio:.2}x",
        simd.name
    );

    // Full push handling (w̃ bookkeeping + prox + seqlock store publish).
    let spec = SynthSpec {
        samples: 64,
        geometry: BlockGeometry::new(8, 64),
        nnz_per_row: 8,
        blocks_per_worker: 8,
        shared_blocks: 1,
        ..Default::default()
    };
    let (_, shards) = gen_partitioned(&spec, 4);
    let topo = Topology::build(&shards, 8, 1);
    let store = Arc::new(BlockStore::new(8, 64));
    let problem = Problem::new(LossKind::Logistic, 1e-5, 1e4);
    let srv = ServerShard::new(0, &topo, store, problem, 4.0, 0.01);
    let block = srv.owned_blocks()[0];
    let worker = topo.workers_of_block[block][0];
    let msg = PushMsg {
        worker,
        block,
        w: vec![0.3f32; 64].into(),
        worker_epoch: 0,
        z_version_used: 0,
        block_seq: 0,
        sent_at: None,
        recycle: None,
    };
    h.bench("server handle_push (native, db=64)", || {
        srv.handle_push(&msg, &asybadmm::coordinator::ProxBackend::Native).unwrap();
    });

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Err(_) => println!("(skipping XLA prox: run `make artifacts`)"),
        Ok(m) => {
            for db in [64usize, 512] {
                let Ok(sp) = ServerProxXla::load(&m, db) else { continue };
                let zt = vec![0.1f32; db];
                let ws = vec![0.2f32; db];
                let r = h.bench(&format!("xla    server_prox db={db}"), || {
                    sp.prox(&zt, &ws, 0.01, 16.0, 1e-5, 1e4).unwrap();
                });
                println!("  -> {:.1} Melem/s (incl. PJRT dispatch)", db as f64 / r.mean_s / 1e6);
            }
        }
    }
    println!("\n{}", h.csv());

    if json_requested() {
        emit_hotpath_json(
            "server_prox",
            &h,
            &[
                ("prox_unrolled_vs_scalar", prox_ratio),
                ("wsum_unrolled_vs_scalar", wsum_ratio),
                ("simd_prox_speedup", simd_prox_ratio),
            ],
        );
    }
}

"""Kernel-vs-oracle correctness: the CORE numeric signal of the repo.

The Pallas kernels (interpret=True) must match the pure-jnp oracles in
kernels/ref.py to tight tolerance across hypothesis-generated shapes,
offsets, and data distributions, and the fused gradient must also match
jax.grad of the scalar objective (independent derivation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import logistic as lk
from compile.kernels import prox as pk
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

KINDS = ("logistic", "squared")


def make_data(rng, m, d, label_kind):
    a = rng.standard_normal((m, d)).astype(np.float32)
    if label_kind == "logistic":
        labels = rng.choice([-1.0, 1.0], size=m).astype(np.float32)
    else:
        labels = rng.standard_normal(m).astype(np.float32)
    weights = (rng.random(m) < 0.9).astype(np.float32) / max(m, 1)
    z = (rng.standard_normal(d) * 0.5).astype(np.float32)
    return a, labels, weights, z


@st.composite
def grad_cases(draw):
    tile_m = draw(st.sampled_from([8, 16, 32]))
    n_tiles = draw(st.integers(1, 4))
    db = draw(st.sampled_from([4, 8, 16]))
    n_blocks = draw(st.integers(1, 4))
    slot = draw(st.integers(0, n_blocks - 1))
    seed = draw(st.integers(0, 2**31 - 1))
    kind = draw(st.sampled_from(KINDS))
    return tile_m, n_tiles, db, n_blocks, slot, seed, kind


@settings(max_examples=40, deadline=None)
@given(grad_cases())
def test_grad_block_matches_ref(case):
    tile_m, n_tiles, db, n_blocks, slot, seed, kind = case
    m, d = tile_m * n_tiles, db * n_blocks
    rng = np.random.default_rng(seed)
    a, labels, weights, z = make_data(rng, m, d, kind)
    off = np.array([slot * db], dtype=np.int32)

    kernel = lk.grad_block(kind, tile_m=tile_m, db=db)
    g, loss = kernel(off, a, labels, weights, z)
    g_ref, loss_ref = ref.grad_block_ref(kind, off, a, labels, weights, z, db)

    np.testing.assert_allclose(g, g_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(loss, loss_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", KINDS)
def test_grad_block_matches_jax_grad(kind):
    """Independent derivation: kernel block-grad == jax.grad slice."""
    m, d, db, tile_m = 64, 32, 8, 16
    rng = np.random.default_rng(0)
    a, labels, weights, z = make_data(rng, m, d, kind)

    def scalar_obj(zz):
        return ref.objective_ref(kind, a, labels, weights, zz)[0]

    full = jax.grad(scalar_obj)(jnp.asarray(z))
    kernel = lk.grad_block(kind, tile_m=tile_m, db=db)
    for slot in range(d // db):
        off = np.array([slot * db], dtype=np.int32)
        g, _ = kernel(off, a, labels, weights, z)
        np.testing.assert_allclose(g, full[slot * db:(slot + 1) * db], rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("kind", KINDS)
def test_grad_block_zero_weight_rows_are_padding(kind):
    """Rows with weight 0 (chunk padding) must not affect grad or loss."""
    tile_m, db = 8, 8
    rng = np.random.default_rng(3)
    a, labels, weights, z = make_data(rng, 16, 16, kind)
    weights = np.ones(16, dtype=np.float32) / 16
    a_pad = np.concatenate([a, rng.standard_normal((8, 16)).astype(np.float32)])
    labels_pad = np.concatenate([labels, np.ones(8, dtype=np.float32)])
    weights_pad = np.concatenate([weights, np.zeros(8, dtype=np.float32)])
    off = np.array([8], dtype=np.int32)

    kernel = lk.grad_block(kind, tile_m=tile_m, db=db)
    g0, l0 = kernel(off, a, labels, weights, z)
    g1, l1 = kernel(off, a_pad, labels_pad, weights_pad, z)
    np.testing.assert_allclose(g0, g1, rtol=1e-6)
    np.testing.assert_allclose(l0, l1, rtol=1e-6)


def test_grad_block_zero_columns_are_padding():
    """Zero feature columns (block-slot padding) leave margins unchanged."""
    tile_m, db = 8, 4
    rng = np.random.default_rng(4)
    a, labels, weights, z = make_data(rng, 16, 8, "logistic")
    a_pad = np.concatenate([a, np.zeros((16, 4), dtype=np.float32)], axis=1)
    z_pad = np.concatenate([z, rng.standard_normal(4).astype(np.float32) * 0])
    kernel = lk.grad_block("logistic", tile_m=tile_m, db=db)
    off = np.array([0], dtype=np.int32)
    g0, l0 = kernel(off, a, labels, weights, z)
    g1, l1 = kernel(off, a_pad, labels, weights, z_pad)
    np.testing.assert_allclose(g0, g1, rtol=1e-6)
    np.testing.assert_allclose(l0, l1, rtol=1e-6)


def test_grad_block_rejects_bad_tiling():
    kernel = lk.grad_block("logistic", tile_m=16, db=8)
    a = np.zeros((24, 16), dtype=np.float32)  # 24 % 16 != 0
    with pytest.raises(ValueError):
        kernel(np.array([0], np.int32), a, np.zeros(24, np.float32),
               np.zeros(24, np.float32), np.zeros(16, np.float32))


@st.composite
def prox_cases(draw):
    tile = draw(st.sampled_from([4, 8, 16]))
    n_tiles = draw(st.integers(1, 6))
    seed = draw(st.integers(0, 2**31 - 1))
    gamma = draw(st.floats(0.0, 10.0))
    denom = draw(st.floats(0.5, 500.0))
    lam = draw(st.floats(0.0, 5.0))
    clip = draw(st.floats(0.1, 100.0))
    return tile, n_tiles, seed, gamma, denom, lam, clip


@settings(max_examples=40, deadline=None)
@given(prox_cases())
def test_server_prox_matches_ref(case):
    tile, n_tiles, seed, gamma, denom, lam, clip = case
    db = tile * n_tiles
    rng = np.random.default_rng(seed)
    zt = rng.standard_normal(db).astype(np.float32) * 10
    ws = rng.standard_normal(db).astype(np.float32) * 100
    args = [np.array([v], np.float32) for v in (gamma, denom, lam, clip)]
    out = pk.server_prox(tile=tile)(zt, ws, *args)
    expect = ref.server_prox_ref(zt, ws, *args)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


def test_server_prox_box_constraint():
    """Output always inside [-C, C] (paper Eq. 22 box)."""
    rng = np.random.default_rng(7)
    zt = rng.standard_normal(16).astype(np.float32) * 1e6
    ws = rng.standard_normal(16).astype(np.float32) * 1e6
    out = pk.server_prox(tile=16)(
        zt, ws, *(np.array([v], np.float32) for v in (1.0, 2.0, 0.1, 3.0))
    )
    assert np.all(np.abs(out) <= 3.0 + 1e-6)


def test_server_prox_soft_threshold_kills_small_values():
    """|v| <= lam/denom maps to exactly 0 (sparsity of l1 prox)."""
    zt = np.full(8, 0.5, np.float32)
    ws = np.zeros(8, np.float32)
    out = pk.server_prox(tile=8)(
        zt, ws, *(np.array([v], np.float32) for v in (1.0, 1.0, 0.6, 10.0))
    )
    np.testing.assert_array_equal(np.asarray(out), np.zeros(8, np.float32))


def test_prox_firm_nonexpansiveness():
    """prox is 1-Lipschitz: |prox(u)-prox(v)| <= |u-v| elementwise args."""
    rng = np.random.default_rng(11)
    sc = [np.array([v], np.float32) for v in (0.0, 1.0, 0.3, 50.0)]
    fn = pk.server_prox(tile=8)
    for _ in range(20):
        u = rng.standard_normal(8).astype(np.float32) * 5
        v = rng.standard_normal(8).astype(np.float32) * 5
        zero = np.zeros(8, np.float32)
        pu = np.asarray(fn(zero, u, *sc))
        pv = np.asarray(fn(zero, v, *sc))
        assert np.linalg.norm(pu - pv) <= np.linalg.norm(u - v) + 1e-5


def test_vmem_estimate_reasonable():
    """Default shape set fits the TPU VMEM budget with double buffering."""
    est = lk.vmem_estimate_bytes(tile_m=256, d=4096, db=512)
    assert est < 8 * 1024 * 1024  # half of 16 MiB VMEM
    assert lk.mxu_macs_per_step(2048, 4096, 512) == 2048 * 4096 + 2048 * 512

"""AOT pipeline tests: manifest integrity + HLO text validity.

The emitted text must parse as an HLO module (same grammar
`HloModuleProto::from_text_file` in the rust runtime consumes) and carry
the parameter/result arity the manifest promises.  Numeric execution of
the artifacts is covered by the rust integration tests (`rust/tests/`),
which exercise the exact production load path (xla_extension 0.5.1).
"""

import json

import pytest
from jax._src.lib import xla_client as xc

from compile import aot, shapes


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(out, "tiny")
    return out, manifest


def test_manifest_structure(built):
    out, manifest = built
    assert manifest["version"] == 1
    names = {e["name"] for e in manifest["entries"]}
    ss = shapes.SHAPE_SETS["tiny"]
    m, d, db = ss.m_chunk, ss.d_pad, ss.db
    for kind in ("logistic", "squared"):
        assert f"worker_step_{kind}_{m}x{d}x{db}" in names
        assert f"grad_chunk_{kind}_{m}x{d}x{db}" in names
        assert f"objective_{kind}_{m}x{d}" in names
    assert f"worker_update_{db}" in names
    assert f"server_prox_{db}" in names


def test_manifest_matches_files_and_parses(built):
    out, manifest = built
    for e in manifest["entries"]:
        path = out / e["file"]
        assert path.exists(), e["file"]
        text = path.read_text()
        assert text.startswith("HloModule")
        mod = xc._xla.hlo_module_from_text(text)  # raises if malformed
        assert mod is not None


def test_manifest_io_arity(built):
    out, manifest = built
    ss = shapes.SHAPE_SETS["tiny"]
    by_name = {e["name"]: e for e in manifest["entries"]}
    ws = by_name[f"worker_step_logistic_{ss.m_chunk}x{ss.d_pad}x{ss.db}"]
    assert len(ws["inputs"]) == 7 and len(ws["outputs"]) == 4
    assert ws["inputs"][0]["shape"] == [ss.m_chunk, ss.d_pad]
    sp = by_name[f"server_prox_{ss.db}"]
    assert len(sp["inputs"]) == 6 and len(sp["outputs"]) == 1
    # text must declare the same number of entry parameters
    text = (out / ws["file"]).read_text()
    entry = [l for l in text.splitlines() if "parameter(" in l]
    assert len(entry) >= 7


def test_manifest_json_loadable(built):
    out, _ = built
    data = json.loads((out / "manifest.json").read_text())
    assert {e["entry"] for e in data["entries"]} == {
        "worker_step", "grad_chunk", "objective", "worker_update", "server_prox",
    }


def test_build_is_incremental(built):
    out, manifest = built
    mtimes = {e["file"]: (out / e["file"]).stat().st_mtime_ns for e in manifest["entries"]}
    aot.build(out, "tiny")  # second run: no-op
    for f, t in mtimes.items():
        assert (out / f).stat().st_mtime_ns == t


def test_force_rebuilds(built):
    out, manifest = built
    f = manifest["entries"][0]["file"]
    before = (out / f).stat().st_mtime_ns
    aot.build(out, "tiny", force=True)
    assert (out / f).stat().st_mtime_ns >= before

"""L2 model graph tests: worker step algebra, fused step vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand_blk(rng, db):
    return rng.standard_normal(db).astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 16, 64]),
       st.floats(0.5, 500.0))
def test_worker_update_matches_ref(seed, db, rho):
    rng = np.random.default_rng(seed)
    g, y, z = rand_blk(rng, db), rand_blk(rng, db), rand_blk(rng, db)
    rho_a = np.array([rho], np.float32)
    w, y_new, x = model.worker_update(g, y, z, rho_a)
    w_r, y_r, x_r = ref.worker_update_ref(g, y, z, rho_a)
    np.testing.assert_allclose(w, w_r, rtol=1e-6)
    np.testing.assert_allclose(y_new, y_r, rtol=1e-6)
    np.testing.assert_allclose(x, x_r, rtol=1e-6)


def test_worker_update_dual_identity():
    """Eq. 25: after Eqs. 11+12, y_new == -g exactly."""
    rng = np.random.default_rng(1)
    g, y, z = rand_blk(rng, 32), rand_blk(rng, 32), rand_blk(rng, 32)
    _, y_new, _ = model.worker_update(g, y, z, np.array([100.0], np.float32))
    # f32 round-trip through *rho and /rho costs a few ulp
    np.testing.assert_allclose(np.asarray(y_new), -g, rtol=1e-4, atol=1e-5)


def test_worker_update_w_identity():
    """w = rho*x + y' = rho*z - 2g - y (closed form)."""
    rng = np.random.default_rng(2)
    g, y, z = rand_blk(rng, 16), rand_blk(rng, 16), rand_blk(rng, 16)
    rho = 7.5
    w, _, _ = model.worker_update(g, y, z, np.array([rho], np.float32))
    np.testing.assert_allclose(np.asarray(w), rho * z - 2 * g - y, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("kind", ("logistic", "squared"))
def test_worker_step_fused_matches_composition(kind):
    m, d, db, tile_m = 32, 32, 8, 16
    rng = np.random.default_rng(5)
    a = rng.standard_normal((m, d)).astype(np.float32)
    labels = rng.choice([-1.0, 1.0], m).astype(np.float32)
    weights = np.full(m, 1.0 / m, np.float32)
    z = rng.standard_normal(d).astype(np.float32)
    y = rand_blk(rng, db)
    off = np.array([2 * db], np.int32)
    rho = np.array([50.0], np.float32)

    step = model.worker_step(kind, tile_m=tile_m, db=db)
    w, y_new, x, loss = step(a, labels, weights, z, y, off, rho)

    g_ref, loss_ref = ref.grad_block_ref(kind, off, a, labels, weights, z, db)
    z_blk = z[2 * db:3 * db]
    w_r, y_r, x_r = ref.worker_update_ref(g_ref, y, z_blk, rho)
    np.testing.assert_allclose(w, w_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(y_new, y_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(x, x_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(loss, loss_ref, rtol=1e-5)


@pytest.mark.parametrize("kind", ("logistic", "squared"))
def test_objective_chunk(kind):
    m, d = 16, 8
    rng = np.random.default_rng(9)
    a = rng.standard_normal((m, d)).astype(np.float32)
    labels = rng.choice([-1.0, 1.0], m).astype(np.float32)
    weights = np.full(m, 1.0 / m, np.float32)
    x = rng.standard_normal(d).astype(np.float32)
    out = model.objective_chunk(kind)(a, labels, weights, x)
    expect = ref.objective_ref(kind, a, labels, weights, x)
    np.testing.assert_allclose(out, expect, rtol=1e-6)


def test_logistic_loss_at_zero_is_log2():
    """Sanity anchor: x=0 -> mean loss = log 2 (used by rust tests too)."""
    m, d = 16, 8
    rng = np.random.default_rng(10)
    a = rng.standard_normal((m, d)).astype(np.float32)
    labels = rng.choice([-1.0, 1.0], m).astype(np.float32)
    weights = np.full(m, 1.0 / m, np.float32)
    out = model.objective_chunk("logistic")(a, labels, weights, np.zeros(d, np.float32))
    np.testing.assert_allclose(out, [np.log(2.0)], rtol=1e-6)


@pytest.mark.parametrize("kind", ("logistic", "squared"))
def test_worker_step_jnp_variant_matches_pallas(kind):
    """The --cpu-fused AOT variant must agree with the Pallas lowering."""
    m, d, db, tile_m = 32, 32, 8, 16
    rng = np.random.default_rng(21)
    a = rng.standard_normal((m, d)).astype(np.float32)
    labels = rng.choice([-1.0, 1.0], m).astype(np.float32)
    weights = np.full(m, 1.0 / m, np.float32)
    z = rng.standard_normal(d).astype(np.float32)
    y = rand_blk(rng, db)
    off = np.array([db], np.int32)
    rho = np.array([2.0], np.float32)

    pallas = model.worker_step(kind, tile_m=tile_m, db=db, use_pallas=True)
    jnp_v = model.worker_step(kind, tile_m=tile_m, db=db, use_pallas=False)
    outs_p = pallas(a, labels, weights, z, y, off, rho)
    outs_j = jnp_v(a, labels, weights, z, y, off, rho)
    for p_out, j_out in zip(outs_p, outs_j):
        np.testing.assert_allclose(p_out, j_out, rtol=1e-4, atol=1e-5)

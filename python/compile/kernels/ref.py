"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has an entry here written in the most obvious
vectorized jnp form (no tiling, no fusion tricks).  pytest compares kernel
vs oracle across hypothesis-generated shapes; the rust integration tests
compare the AOT-compiled artifacts against numbers produced from these same
formulas re-implemented natively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def loss_terms(kind: str, margins, labels, weights):
    """(per-sample loss, per-sample dloss/dmargin), weight-scaled."""
    if kind == "logistic":
        t = -labels * margins
        return weights * jnp.logaddexp(0.0, t), -labels * jax.nn.sigmoid(t) * weights
    if kind == "squared":
        r = margins - labels
        return 0.5 * weights * r * r, weights * r
    raise ValueError(f"unknown loss kind {kind!r}")


def grad_block_ref(kind, offset, a, labels, weights, z, db):
    """Oracle for kernels.logistic.grad_block."""
    margins = a @ z
    loss, slope = loss_terms(kind, margins, labels, weights)
    a_blk = jax.lax.dynamic_slice(a, (0, offset[0]), (a.shape[0], db))
    return a_blk.T @ slope, jnp.sum(loss)[None]


def full_grad_ref(kind, a, labels, weights, z):
    """Full local gradient (all columns), for jax.grad cross-checks."""
    margins = a @ z
    _, slope = loss_terms(kind, margins, labels, weights)
    return a.T @ slope


def objective_ref(kind, a, labels, weights, x):
    margins = a @ x
    loss, _ = loss_terms(kind, margins, labels, weights)
    return jnp.sum(loss)[None]


def soft_threshold(v, thr):
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - thr, 0.0)


def server_prox_ref(z_tilde, w_sum, gamma, denom, lam, clip):
    """Oracle for kernels.prox.server_prox (Eq. 13 with l1 + box)."""
    v = (gamma[0] * z_tilde + w_sum) / denom[0]
    return jnp.clip(soft_threshold(v, lam[0] / denom[0]), -clip[0], clip[0])


def worker_update_ref(g_blk, y_blk, z_blk, rho):
    """Oracle for the Eq. 9/11/12 epilogue.

    x  = z~ - (g + y)/rho          (Eq. 11)
    y' = y + rho (x - z~) = -g     (Eq. 12; the -g identity is Eq. 25)
    w  = rho x + y'                (Eq. 9)
    """
    x = z_blk - (g_blk + y_blk) / rho[0]
    y_new = y_blk + rho[0] * (x - z_blk)
    w = rho[0] * x + y_new
    return w, y_new, x

"""L1: Pallas kernels for AsyBADMM's compute hot-spots + jnp oracles."""

from . import logistic, prox, ref  # noqa: F401

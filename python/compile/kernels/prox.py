"""L1: server-side proximal update Pallas kernel (paper Eq. 13).

The server shard owning block j applies, upon receiving a worker push,

    z_j <- prox_h^mu( (gamma * z~_j + sum_i w~_ij) / (gamma + sum_i rho_i) )

with h = lam * ||.||_1 plus the box constraint |z| <= C (paper Eq. 22),
whose proximal operator is soft-thresholding followed by clipping:

    prox(v) = clip(sign(v) * max(|v| - lam/mu, 0), -C, C)

Elementwise over the block; tiled so arbitrary block sizes stream through
VMEM.  Scalars travel as (1,)-shaped f32 inputs so the AOT-compiled
executable is reusable across hyper-parameter settings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prox_kernel(zt_ref, ws_ref, gamma_ref, denom_ref, lam_ref, clip_ref, out_ref):
    denom = denom_ref[0]
    v = (gamma_ref[0] * zt_ref[...] + ws_ref[...]) / denom
    thr = lam_ref[0] / denom
    soft = jnp.sign(v) * jnp.maximum(jnp.abs(v) - thr, 0.0)
    out_ref[...] = jnp.clip(soft, -clip_ref[0], clip_ref[0])


def server_prox(*, tile: int, interpret: bool = True):
    """Build ``fn(z_tilde[db], w_sum[db], gamma[1], denom[1], lam[1],
    clip[1]) -> z_new[db]`` with ``db % tile == 0``."""

    def fn(z_tilde, w_sum, gamma, denom, lam, clip):
        (db,) = z_tilde.shape
        if db % tile:
            raise ValueError(f"db={db} not a multiple of tile={tile}")
        grid = (db // tile,)
        scalar = pl.BlockSpec((1,), lambda i: (0,))
        return pl.pallas_call(
            _prox_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile,), lambda i: (i,)),
                pl.BlockSpec((tile,), lambda i: (i,)),
                scalar,
                scalar,
                scalar,
                scalar,
            ],
            out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((db,), jnp.float32),
            interpret=interpret,
        )(z_tilde, w_sum, gamma, denom, lam, clip)

    return fn

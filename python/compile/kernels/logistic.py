"""L1: fused margin + block-gradient Pallas kernels.

The compute hot-spot of AsyBADMM's worker step (Eq. 11 of the paper) is
computing the block partial gradient nabla_j f_i(z~) over the worker's local
data shard.  For a generalized linear loss

    f_i(z) = sum_l  wgt_l * phi(<a_l, z>, y_l)

the gradient w.r.t. block j is  A[:, j]^T s  with  s_l = wgt_l *
phi'(<a_l, z>, y_l).  A naive implementation makes two passes over A in HBM
(one for margins A z, one for the block gradient).  The kernel below fuses
them: the grid walks row tiles of A; each tile computes its margins, the
loss-derivative weighting s, and accumulates both the scalar loss and the
block gradient, so A is read exactly once.

TPU mapping (see DESIGN.md section "Hardware adaptation"): both per-tile
matmuls (A_tile @ z and A_blk^T @ s) target the MXU; z and the (db,)
accumulator stay VMEM-resident across the whole grid; the row-tile size is
chosen so tile_m*d + d + db floats fit comfortably in VMEM.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust runtime
(xla crate / xla_extension 0.5.1) compiles and runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Loss kinds supported by the fused kernel.  Each entry maps a margin vector
# (m,), labels (m,) and per-sample weights (m,) to (per-sample loss,
# per-sample dloss/dmargin), both already weight-scaled.
#
#   logistic:  phi(m, y) = log(1 + exp(-y m))       (paper Eq. 22)
#   squared:   phi(m, y) = 0.5 (m - y)^2            (lasso / robust MC)
LOSS_KINDS = ("logistic", "squared")


def _loss_and_slope(kind: str, margins, labels, weights):
    if kind == "logistic":
        t = -labels * margins
        loss = weights * jnp.logaddexp(0.0, t)
        slope = -labels * jax.nn.sigmoid(t) * weights
    elif kind == "squared":
        r = margins - labels
        loss = 0.5 * weights * r * r
        slope = weights * r
    else:  # pragma: no cover - guarded by LOSS_KINDS
        raise ValueError(f"unknown loss kind {kind!r}")
    return loss, slope


def _grad_block_kernel(
    off_ref, a_ref, y_ref, w_ref, z_ref, g_ref, loss_ref, *, kind: str, db: int
):
    """One grid step: row tile of A -> partial (g_blk, loss) accumulation."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    a = a_ref[...]  # (tile_m, d)  — single HBM read of this tile
    margins = a @ z_ref[...]  # (tile_m,)   MXU matmul #1
    loss, slope = _loss_and_slope(kind, margins, y_ref[...], w_ref[...])
    loss_ref[...] += jnp.sum(loss)[None]
    off = off_ref[0]
    a_blk = jax.lax.dynamic_slice(a, (0, off), (a.shape[0], db))
    g_ref[...] += a_blk.T @ slope  # (db,)   MXU matmul #2


def grad_block(kind: str, *, tile_m: int, db: int, interpret: bool = True):
    """Build the fused block-gradient function.

    Returns ``fn(offset_i32[1], A[m,d], labels[m], weights[m], z[d]) ->
    (g_blk[db], loss[1])`` where ``m % tile_m == 0`` (pad rows with
    weight 0) and ``offset + db <= d`` with ``offset % db == 0``.
    """
    if kind not in LOSS_KINDS:
        raise ValueError(f"unknown loss kind {kind!r}")

    kernel = functools.partial(_grad_block_kernel, kind=kind, db=db)

    def fn(offset, a, labels, weights, z):
        m, d = a.shape
        if m % tile_m:
            raise ValueError(f"m={m} not a multiple of tile_m={tile_m}")
        grid = (m // tile_m,)
        g, loss = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1,), lambda i: (0,)),  # offset
                pl.BlockSpec((tile_m, d), lambda i: (i, 0)),  # A row tile
                pl.BlockSpec((tile_m,), lambda i: (i,)),  # labels
                pl.BlockSpec((tile_m,), lambda i: (i,)),  # weights
                pl.BlockSpec((d,), lambda i: (0,)),  # z (VMEM-resident)
            ],
            out_specs=[
                pl.BlockSpec((db,), lambda i: (0,)),  # g accumulator
                pl.BlockSpec((1,), lambda i: (0,)),  # loss accumulator
            ],
            out_shape=[
                jax.ShapeDtypeStruct((db,), jnp.float32),
                jax.ShapeDtypeStruct((1,), jnp.float32),
            ],
            interpret=interpret,
        )(offset, a, labels, weights, z)
        return g, loss

    return fn


def vmem_estimate_bytes(tile_m: int, d: int, db: int) -> int:
    """Static VMEM footprint estimate (f32) for one grid step.

    Used by DESIGN.md section 9 / the perf notes: A tile + z + labels +
    weights + margins + g accumulator.  Real-TPU sizing keeps this under
    ~half of the 16 MiB VMEM to allow double buffering of the A tile.
    """
    floats = tile_m * d + d + 3 * tile_m + db + 1
    return 4 * floats


def mxu_macs_per_step(m: int, d: int, db: int) -> int:
    """MACs per fused worker-gradient invocation (both matmuls)."""
    return m * d + m * db

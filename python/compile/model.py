"""L2: AsyBADMM compute graphs in JAX, composing the L1 Pallas kernels.

These are the *numerical* pieces of Algorithm 1 — everything a worker or a
server shard computes per message, with all coordination stripped out (the
rust L3 owns loops, topology, versions, delays).  Each public function here
is an AOT entry point lowered once by ``aot.py`` to HLO text and executed
from rust via PJRT; Python never runs on the request path.

Shape conventions (static per compiled artifact, see shapes.py):

  m_chunk : rows per data chunk.  A worker's shard is stored as fixed-size
            row chunks (last chunk zero-padded with weight 0) so artifact
            shapes are independent of the worker count p.
  d_pad   : padded local feature width = max_active_blocks * db.  Each
            worker packs its active blocks into slots [0, n_active); unused
            slots are zero columns (zero columns contribute nothing to
            margins, so numerics are exact).
  db      : block size (one consensus block z_j per server slot).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import logistic as lk
from .kernels import prox as pk
from .kernels import ref


def grad_chunk(
    kind: str, *, tile_m: int, db: int, interpret: bool = True, use_pallas: bool = True
):
    """AOT entry: fused block gradient over one data chunk.

    fn(A[m,d], labels[m], weights[m], z_local[d], offset i32[1])
        -> (g_blk[db], loss[1])

    ``use_pallas=False`` lowers the same math through plain jnp instead
    of the interpret-mode Pallas kernel: XLA:CPU fuses it ~4x faster
    (EXPERIMENTS.md §Perf) because the Pallas interpreter's per-step
    buffer shuffling disappears.  The Pallas kernel remains the default
    (and the real-TPU lowering); both variants are verified against
    kernels/ref.py by pytest.
    """
    if not use_pallas:
        def fn(a, labels, weights, z_local, offset):
            return ref.grad_block_ref(kind, offset, a, labels, weights, z_local, db)

        return fn

    kernel = lk.grad_block(kind, tile_m=tile_m, db=db, interpret=interpret)

    def fn(a, labels, weights, z_local, offset):
        return kernel(offset, a, labels, weights, z_local)

    return fn


def worker_update(g_blk, y_blk, z_blk, rho):
    """AOT entry: the Eq. 9/11/12 epilogue after the block gradient.

    fn(g_blk[db], y_blk[db], z_blk[db], rho f32[1])
        -> (w_blk[db], y_new[db], x_blk[db])
    """
    x = z_blk - (g_blk + y_blk) / rho[0]
    y_new = y_blk + rho[0] * (x - z_blk)
    w = rho[0] * x + y_new
    return w, y_new, x


def worker_step(
    kind: str, *, tile_m: int, db: int, interpret: bool = True, use_pallas: bool = True
):
    """AOT entry: fully fused worker iteration (gradient + epilogue).

    fn(A[m,d], labels[m], weights[m], z_local[d], y_blk[db],
       offset i32[1], rho f32[1])
        -> (w_blk[db], y_new[db], x_blk[db], loss[1])

    Single-chunk workers use this one executable per iteration; multi-chunk
    workers run grad_chunk per chunk, sum gradients in rust, then apply
    worker_update.
    """
    gfn = grad_chunk(kind, tile_m=tile_m, db=db, interpret=interpret, use_pallas=use_pallas)

    def fn(a, labels, weights, z_local, y_blk, offset, rho):
        g_blk, loss = gfn(a, labels, weights, z_local, offset)
        z_blk = jax.lax.dynamic_slice(z_local, (offset[0],), (db,))
        w, y_new, x = worker_update(g_blk, y_blk, z_blk, rho)
        return w, y_new, x, loss

    return fn


def server_prox(*, tile: int, interpret: bool = True):
    """AOT entry: server-side block update, Eq. 13 with h = l1 + box.

    fn(z_tilde[db], w_sum[db], gamma f32[1], denom f32[1], lam f32[1],
       clip f32[1]) -> z_new[db]
    """
    return pk.server_prox(tile=tile, interpret=interpret)


def objective_chunk(kind: str):
    """AOT entry: data-term objective over one chunk (metric logging only;
    h(z) is accumulated in rust where the full z lives).

    fn(A[m,d], labels[m], weights[m], x[d]) -> loss[1]
    """

    def fn(a, labels, weights, x):
        return ref.objective_ref(kind, a, labels, weights, x)

    return fn


@functools.lru_cache(maxsize=None)
def _jitted(kind, tile_m, db):
    """Cached jitted worker_step for python-side tests."""
    return jax.jit(worker_step(kind, tile_m=tile_m, db=db))

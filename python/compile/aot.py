"""AOT pipeline: lower L2 entry points to HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` rust crate binds) rejects with
``proto.id() <= INT_MAX``; the text parser reassigns ids and round-trips
cleanly.  Lowered with ``return_tuple=True`` — rust unwraps with
``to_tuple1/2/4``.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts \
                            --shapes default,small,tiny

Runs once at build time (`make artifacts`); never on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapes

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(fn, arg_specs) -> str:
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _io(spec_list):
    return [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in spec_list]


def entries_for(ss: shapes.ShapeSet, use_pallas: bool = True):
    """Yield (name, fn, arg_specs, out_specs, meta) for one shape set."""
    m, d, db = ss.m_chunk, ss.d_pad, ss.db
    a, lab, wgt, z = spec((m, d)), spec((m,)), spec((m,)), spec((d,))
    blk, sc, off = spec((db,)), spec((1,)), spec((1,), I32)
    meta = dict(
        shape_set=ss.name, m_chunk=m, d_pad=d, db=db, tile_m=ss.tile_m,
        prox_tile=ss.prox_tile, variant="pallas" if use_pallas else "jnp",
    )
    for kind in ("logistic", "squared"):
        km = dict(meta, kind=kind)
        yield (
            f"worker_step_{kind}_{m}x{d}x{db}",
            model.worker_step(kind, tile_m=ss.tile_m, db=db, use_pallas=use_pallas),
            [a, lab, wgt, z, blk, off, sc],
            [blk, blk, blk, spec((1,))],
            dict(km, entry="worker_step"),
        )
        yield (
            f"grad_chunk_{kind}_{m}x{d}x{db}",
            model.grad_chunk(kind, tile_m=ss.tile_m, db=db, use_pallas=use_pallas),
            [a, lab, wgt, z, off],
            [blk, spec((1,))],
            dict(km, entry="grad_chunk"),
        )
        yield (
            f"objective_{kind}_{m}x{d}",
            model.objective_chunk(kind),
            [a, lab, wgt, z],
            [spec((1,))],
            dict(km, entry="objective"),
        )
    yield (
        f"worker_update_{db}",
        model.worker_update,
        [blk, blk, blk, sc],
        [blk, blk, blk],
        dict(meta, entry="worker_update", kind="any"),
    )
    yield (
        f"server_prox_{db}",
        model.server_prox(tile=ss.prox_tile),
        [blk, blk, sc, sc, sc, sc],
        [blk],
        dict(meta, entry="server_prox", kind="any"),
    )


def build(
    out_dir: pathlib.Path, shape_names: str, force: bool = False, use_pallas: bool = True
) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    old = {}
    if manifest_path.exists() and not force:
        try:
            old = {e["name"]: e for e in json.loads(manifest_path.read_text())["entries"]}
        except Exception:
            old = {}
    entries = []
    seen = set()
    for ss in shapes.resolve(shape_names):
        for name, fn, arg_specs, out_specs, meta in entries_for(ss, use_pallas):
            if name in seen:  # worker_update/server_prox can collide across sets
                continue
            seen.add(name)
            fname = f"{name}.hlo.txt"
            path = out_dir / fname
            prev = old.get(name)
            # Reuse only if the generation parameters are unchanged
            # (tile sizes matter even though they are not in the name).
            unchanged = prev is not None and all(
                prev.get(k) == v for k, v in meta.items()
            )
            if unchanged and path.exists() and not force:
                entries.append(prev)
                continue
            text = to_hlo_text(fn, arg_specs)
            path.write_text(text)
            entries.append(
                dict(
                    meta,
                    name=name,
                    file=fname,
                    inputs=_io(arg_specs),
                    outputs=_io(out_specs),
                    sha256=hashlib.sha256(text.encode()).hexdigest()[:16],
                )
            )
            print(f"  wrote {fname} ({len(text)} chars)")
    manifest = {"version": 1, "entries": entries}
    manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"manifest: {manifest_path} ({len(entries)} artifacts)")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--shapes", default="default,small,tiny")
    p.add_argument("--force", action="store_true")
    p.add_argument(
        "--cpu-fused",
        action="store_true",
        help="lower the gradient hot-spot through plain jnp instead of the "
        "interpret-mode Pallas kernel (faster on CPU; see EXPERIMENTS.md §Perf)",
    )
    args = p.parse_args()
    build(pathlib.Path(args.out_dir), args.shapes, args.force, use_pallas=not args.cpu_fused)


if __name__ == "__main__":
    main()

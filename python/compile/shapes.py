"""Static shape configurations for the AOT artifact build.

Each ShapeSet yields a family of artifacts whose names encode the shapes,
so the rust runtime can pick executables by (m_chunk, d_pad, db) from
``artifacts/manifest.json``.  Keep these in sync with rust `config`
defaults (rust reads the manifest, so a mismatch fails loudly at startup,
not silently).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator


@dataclasses.dataclass(frozen=True)
class ShapeSet:
    name: str
    m_chunk: int  # rows per data chunk
    d_pad: int  # padded local feature width (= max_active_blocks * db)
    db: int  # consensus block size
    tile_m: int  # kernel row-tile
    prox_tile: int  # prox kernel tile

    def __post_init__(self):
        assert self.m_chunk % self.tile_m == 0
        assert self.d_pad % self.db == 0
        assert self.db % self.prox_tile == 0


# "default": the Fig.2 / Table 1 reproduction scale (synthetic KDDa-like).
# "small":  quickstart + rust integration tests.
# "tiny":   python pytest round-trips and CI smoke.
# PERF (EXPERIMENTS.md §Perf, L1): on the CPU-interpret path every Pallas
# grid step pays interpreter dispatch + buffer shuffling, which dominates
# the actual GEMV work; tile_m == m_chunk collapses the grid to one step
# per chunk (~8x faster end-to-end on this machine).  On a real TPU the
# row tile must instead fit VMEM (tile_m=256 at d_pad=4096 uses ~4.2 MB,
# allowing double buffering); `ShapeSet.tpu_tile_m` records that sizing
# and kernels would use it when lowered without interpret=True.
SHAPE_SETS = {
    "default": ShapeSet("default", m_chunk=2048, d_pad=4096, db=512, tile_m=2048, prox_tile=512),
    "small": ShapeSet("small", m_chunk=256, d_pad=512, db=64, tile_m=256, prox_tile=64),
    "tiny": ShapeSet("tiny", m_chunk=32, d_pad=64, db=16, tile_m=32, prox_tile=16),
}

# TPU VMEM-sized row tiles per set (documentation + real-TPU lowering).
TPU_TILE_M = {"default": 256, "small": 64, "tiny": 16}


def resolve(names: str) -> Iterator[ShapeSet]:
    """'default,small' -> ShapeSets; 'all' -> everything."""
    if names == "all":
        yield from SHAPE_SETS.values()
        return
    for n in names.split(","):
        n = n.strip()
        if n not in SHAPE_SETS:
            raise KeyError(f"unknown shape set {n!r}; have {sorted(SHAPE_SETS)}")
        yield SHAPE_SETS[n]

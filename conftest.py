import sys
import pathlib

# Make `python/compile` importable when pytest runs from the repo root.
sys.path.insert(0, str(pathlib.Path(__file__).parent / "python"))

//! Lasso (l1-regularized least squares) via general-form consensus — the
//! second problem instance, showing the framework is problem-generic:
//! same coordinator, same artifacts pipeline (kind="squared"), different
//! Problem.  Reports support recovery against the synthetic ground truth.
//!
//!     cargo run --release --example lasso

use asybadmm::config::Config;
use asybadmm::coordinator::Session;
use asybadmm::data::{gen_partitioned, LossKind};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::small();
    cfg.loss = LossKind::Squared;
    cfg.lambda = 2e-4;
    cfg.rho = 4.0;
    cfg.epochs = 600;
    cfg.log_every = 60;
    cfg.noise = 0.02;

    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    println!(
        "lasso: {} samples x {} features ({} blocks), lambda={}",
        ds.samples(),
        ds.dim(),
        cfg.n_blocks,
        cfg.lambda
    );

    let report = Session::builder(&cfg).dataset(&ds, &shards).run()?;
    for s in &report.samples {
        println!("  epoch {:>5}  obj {:.6}", s.epoch, s.objective);
    }

    let z = &report.z_final;
    let nnz = z.iter().filter(|v| v.abs() > 1e-6).count();
    println!(
        "\nfinal objective {:.6}; recovered support: {nnz}/{} coefficients non-zero",
        report.final_objective.total(),
        z.len()
    );

    // Sweep lambda to show the regularization path (more l1 => sparser).
    println!("\nregularization path (same data, 300 epochs):");
    println!("{:>10} {:>12} {:>8}", "lambda", "objective", "nnz");
    for lam in [0.0f32, 1e-4, 5e-4, 2e-3] {
        let mut c = cfg.clone();
        c.lambda = lam;
        c.epochs = 300;
        c.log_every = 1000;
        let r = Session::builder(&c).dataset(&ds, &shards).run()?;
        let nnz = r.z_final.iter().filter(|v| v.abs() > 1e-6).count();
        println!("{:>10.1e} {:>12.6} {:>8}", lam, r.final_objective.total(), nnz);
    }
    Ok(())
}

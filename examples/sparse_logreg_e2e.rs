//! END-TO-END DRIVER (DESIGN.md experiment E7, the mandated validation):
//! the full three-layer stack on a real small workload.
//!
//!   L1/L2  Pallas fused gradient kernel + prox kernel, lowered by
//!          `make artifacts` to HLO text;
//!   L3     this binary: rust parameter-server runtime loads the
//!          artifacts via PJRT and trains sparse logistic regression
//!          (paper Eq. 22) asynchronously with 4 workers / 2 servers,
//!          logging the loss curve.
//!
//!     make artifacts && cargo run --release --example sparse_logreg_e2e
//!
//! Writes reports/e2e_trace.csv and reports/e2e_record.json; the run is
//! recorded in EXPERIMENTS.md §E7.

use std::path::Path;

use asybadmm::config::{Backend, Config};
use asybadmm::coordinator::Session;
use asybadmm::data::gen_partitioned;
use asybadmm::report::{run_record, write_file, write_trace_csv};

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    // "small" artifact shape set: m_chunk=256, d_pad=512, db=64.
    let mut cfg = Config::small();
    cfg.backend = Backend::Xla;
    cfg.epochs = 1200;
    cfg.log_every = 100;
    cfg.samples = 4096; // multi-chunk shards: 1024 rows -> 4 chunks/worker
    // rho scaled to the 1/m-weighted Lipschitz constants of this
    // workload (see admm::penalty); 4L ~= 0.5 here.
    cfg.rho = 1.5;
    cfg.artifacts_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.validate()?;

    println!("== AsyBADMM end-to-end (three-layer, XLA on the hot path) ==");
    println!("config: {}", cfg.summary());

    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    println!(
        "dataset {}: {} samples x {} features, {} nnz ({}x{} blocks)",
        ds.name,
        ds.samples(),
        ds.dim(),
        ds.a.nnz(),
        cfg.n_blocks,
        cfg.block_size
    );

    let report = Session::builder(&cfg).dataset(&ds, &shards).run()?;

    println!("\nloss curve (objective = mean logistic loss + l1):");
    for s in &report.samples {
        println!("  epoch {:>5}  t={:>8.3}s  obj {:.6}", s.epoch, s.time_s, s.objective);
    }
    let first = report.samples.first().unwrap().objective;
    let last = report.final_objective.total();
    println!("\nobjective {first:.6} -> {last:.6} ({:.1}% reduction)", 100.0 * (1.0 - last / first));
    println!(
        "consensus gap {:.2e}  stationarity {:.2e}  pushes {}  staleness<= {}",
        report.consensus_max,
        report.stationarity,
        report.total_pushes(),
        report.max_staleness()
    );

    write_trace_csv(Path::new("reports/e2e_trace.csv"), &report.samples)?;
    let record = run_record(
        "E7-e2e-sparse-logreg-xla",
        &cfg.summary(),
        vec![
            ("objective_first", first),
            ("objective_final", last),
            ("elapsed_s", report.elapsed_s),
            ("pushes", report.total_pushes() as f64),
            ("max_staleness", report.max_staleness() as f64),
            ("stationarity", report.stationarity),
        ],
    );
    write_file(Path::new("reports/e2e_record.json"), &record.to_string_pretty())?;
    println!(
        "\nwrote reports/e2e_trace.csv, reports/e2e_record.json  (total {:.1}s)",
        t0.elapsed().as_secs_f64()
    );

    anyhow::ensure!(last < first * 0.85, "e2e validation failed: loss did not drop 15%");
    println!("E2E VALIDATION PASSED: all three layers compose.");
    Ok(())
}

//! E6 validation: Theorem 1's convergence certificates, measured.
//!
//! Tracks the paper's Eq. 14 stationarity residual P(X,Y,z), the
//! consensus gap max‖x_ij − z_j‖, and the objective across increasing
//! iteration budgets — all three must decay toward 0 / a fixed point,
//! and the KKT identities (Eqs. 20a-20c) must hold approximately at the
//! final iterate.
//!
//!     cargo run --release --example stationarity

use asybadmm::config::Config;
use asybadmm::coordinator::Session;
use asybadmm::data::gen_partitioned;

fn main() -> anyhow::Result<()> {
    let mut base = Config::small();
    base.samples = 2048;
    base.log_every = 10_000;

    let (ds, shards) = gen_partitioned(&base.synth_spec(), base.n_workers);
    println!(
        "{:>8} {:>14} {:>14} {:>12}",
        "epochs", "P(X,Y,z)", "max|x-z|", "objective"
    );
    let budgets = [25usize, 50, 100, 200, 400, 800, 1600];
    let mut rows = Vec::new();
    for &t in &budgets {
        let mut cfg = base.clone();
        cfg.epochs = t;
        let r = Session::builder(&cfg).dataset(&ds, &shards).run()?;
        println!(
            "{t:>8} {:>14.6e} {:>14.6e} {:>12.6}",
            r.stationarity,
            r.consensus_max,
            r.final_objective.total()
        );
        rows.push((t, r.stationarity, r.consensus_max));
    }

    // Decay check (Theorem 1, part 3: T(eps) <= C/eps — i.e. residual
    // within budget T decays like 1/T).
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    println!(
        "\nP decayed {:.1}x over {}x budget (Theorem 1 predicts ~linear in 1/T)",
        first.1 / last.1.max(1e-300),
        last.0 / first.0
    );
    anyhow::ensure!(last.1 < first.1, "stationarity residual did not decay");
    anyhow::ensure!(last.2 < first.2, "consensus gap did not decay");
    println!("KKT trend verified: residual and consensus gap both decay.");
    Ok(())
}

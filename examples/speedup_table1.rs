//! Reproduce paper Table 1: running time (virtual seconds) to complete
//! k ∈ {20, 50, 100} iterations for p ∈ {1, 4, 8, 16, 32} workers, and
//! the speedup column T_k(1)/T_k(p).
//!
//! Experimental semantics match the paper's §5 setup exactly:
//!   * one FIXED dataset, evenly partitioned across p workers
//!     (generated once as 32 virtual shards, regrouped per p);
//!   * "iteration" = one full cycle through the worker's blocks
//!     ("each worker updates the blocks by cycling through the
//!     coordinates of x and updating each in turn");
//!   * KDDa's random partitioning makes every worker touch essentially
//!     every block, so the workload footprint is dense
//!     (blocks_per_worker = n_blocks) — the block-SPARSE regime is
//!     exercised by the e2e example and the ablations instead;
//!   * strong scaling: per-cycle compute shrinks ∝ 1/p while network +
//!     server-service costs stay fixed.
//!
//! Timing is virtual (DES) with per-row compute cost measured on the
//! real AOT XLA `worker_step` artifact at the reference shape; the
//! numerics (every gradient, every prox) run for real.
//!
//!     cargo run --release --example speedup_table1 [-- --quick]
//!
//! Writes reports/table1.md and reports/table1.csv.

use std::path::Path;

use asybadmm::config::{BlockSelection, Config};
use asybadmm::coordinator::{Algo, Session};
use asybadmm::data::gen_virtual_partitioned;
use asybadmm::problem::Problem;
use asybadmm::report::{write_file, SpeedupTable};
use asybadmm::runtime::Manifest;
use asybadmm::sim::{calibrate_native, calibrate_xla, CostModel};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let ks_cycles = vec![20usize, 50, 100];
    let worker_counts = [1usize, 4, 8, 16, 32];

    let mut base = Config::default();
    // Paper §5 workload: dense footprint + cyclic block selection.
    base.blocks_per_worker = base.n_blocks;
    base.selection = BlockSelection::Cyclic;
    // rho sized against the local-mean block Lipschitz constants of the
    // dense-footprint workload (4L ~= 1.25; see admm::penalty).
    base.rho = 1.5;
    base.samples = if quick { 8192 } else { 65536 };
    let cycles = *ks_cycles.last().unwrap();
    base.epochs = cycles * base.n_blocks; // internal epochs = block updates
    base.log_every = 100_000; // objective sampling off the hot path

    println!(
        "Table 1 reproduction — m={}, d={}, k={ks_cycles:?} cycles ({} blocks/cycle)",
        base.samples,
        base.n_blocks * base.block_size,
        base.n_blocks
    );

    // Cost model: per-row rate measured on the real XLA artifact
    // (rows-linear = the sparse row-streaming regime of the paper's
    // ps-lite workers; see DESIGN.md).
    let manifest = Manifest::load(&base.artifacts_dir).ok();
    let cost: CostModel = match &manifest {
        Some(m) => calibrate_xla(m, base.loss, base.block_size, base.m_chunk, base.d_pad)
            .map(|c| {
                let mut c = c.linearized();
                // Shared-tenancy compute variance of the paper's EC2 c4
                // fleet (stragglers bound time-to-k at high p).
                c.compute_jitter = 0.15;
                c
            })
            .unwrap_or_else(|e| {
                eprintln!("xla calibration unavailable ({e:#}); native fallback");
                let (ds, shards) = gen_virtual_partitioned(&base.synth_spec(), 32, 4);
                calibrate_native(&ds, &shards, Problem::new(base.loss, base.lambda, base.clip))
            }),
        None => {
            let (ds, shards) = gen_virtual_partitioned(&base.synth_spec(), 32, 4);
            calibrate_native(&ds, &shards, Problem::new(base.loss, base.lambda, base.clip))
        }
    };
    println!(
        "cost model: {:.2}us/row-per-block-update, service={:.1}us, net={:.0}us",
        cost.compute_per_row_s * 1e6,
        cost.server_service_s * 1e6,
        cost.net_mean_s * 1e6
    );

    let mut rows = Vec::new();
    for &p in &worker_counts {
        let mut cfg = base.clone();
        cfg.n_workers = p;
        let (ds, shards) = gen_virtual_partitioned(&cfg.synth_spec(), 32, p);
        let r = Session::builder(&cfg)
            .dataset(&ds, &shards)
            .algo(Algo::Sim(cost))
            .run()?;
        let sx = r.sim.as_ref().expect("Algo::Sim reports sim extras");
        let ts: Vec<f64> = ks_cycles
            .iter()
            .map(|&k| sx.time_to_epoch[k * base.n_blocks])
            .collect();
        println!(
            "p={p:>2}: t(k=20)={:.1}s t(k=50)={:.1}s t(k=100)={:.1}s (virtual), final obj {:.5}",
            ts[0],
            ts[1],
            ts[2],
            r.final_objective.total()
        );
        rows.push((p, ts));
    }

    let table = SpeedupTable { ks: ks_cycles, rows };
    println!("\n{}", table.to_markdown());
    println!("paper's Table 1 speedups for reference: 1.0 / 3.87 / 7.92 / 16.31 / 29.83");

    write_file(Path::new("reports/table1.md"), &table.to_markdown())?;
    write_file(Path::new("reports/table1.csv"), &table.to_csv())?;
    println!("wrote reports/table1.md, reports/table1.csv");
    Ok(())
}

//! Quickstart: train sparse logistic regression with block-wise
//! asynchronous ADMM on a small synthetic dataset, native backend,
//! through the `Session` builder API.
//!
//!     cargo run --release --example quickstart
//!
//! Shown here:
//!   * `Session::builder(&cfg).dataset(..).run()` — the one entry point
//!     for every execution path (async runtime, baselines, DES);
//!   * a custom `Observer` streaming live progress (the same hook the
//!     built-in objective sampler uses);
//!   * an explicit `Transport` choice — the lock-free per-worker SPSC
//!     ring instead of the default bounded-mpsc channel.
//!
//! For the full three-layer path (JAX/Pallas-compiled XLA artifacts on
//! the hot path), run `make artifacts` first and see
//! `examples/sparse_logreg_e2e.rs`.

use asybadmm::config::{Config, TransportKind};
use asybadmm::coordinator::{make_transport, push_inflight, Observer, Progress, Session};
use asybadmm::data::gen_partitioned;

/// Live progress printer: `on_sample` fires whenever the minimum worker
/// epoch crosses a `log_every` watermark, with a lazily-evaluated view
/// of the consensus iterate.
struct LiveLog;

impl Observer for LiveLog {
    fn on_sample(&mut self, p: &Progress<'_>) {
        let obj = p.objective();
        println!(
            "  [live] epoch {:>5}  t={:>7.3}s  obj {:.6}  (data {:.6})",
            p.epoch,
            p.time_s,
            obj.total(),
            obj.data_loss
        );
    }
}

fn main() -> anyhow::Result<()> {
    // 1. Configure: 2k samples, 16 blocks x 64 features, 4 workers,
    //    2 server shards (the "small" shape set).
    let mut cfg = Config::small();
    cfg.epochs = 400;
    cfg.log_every = 50;

    // 2. Generate a block-sparse synthetic workload (each worker's data
    //    touches only `blocks_per_worker` of the consensus blocks).
    let (ds, shards) = gen_partitioned(&cfg.synth_spec(), cfg.n_workers);
    println!("dataset: {} samples, {} features, {} nnz", ds.samples(), ds.dim(), ds.a.nnz());
    for s in &shards {
        println!(
            "  worker {}: {} rows, active blocks {:?}",
            s.worker_id,
            s.samples(),
            s.active_blocks
        );
    }

    // 3. Train asynchronously (Algorithm 1).  The transport line is
    //    optional — the default comes from `cfg.transport` (settable on
    //    the CLI with `--set transport=mpsc|ring`); it is spelled out
    //    here to show where the queueing discipline plugs in.
    let report = Session::builder(&cfg)
        .dataset(&ds, &shards)
        .transport(make_transport(
            TransportKind::SpscRing,
            cfg.n_workers,
            cfg.n_servers,
            push_inflight(cfg.n_workers),
            cfg.batch,
        ))
        .observer(LiveLog)
        .run()?;

    // 4. Inspect the unified report.
    println!("\n{:>8} {:>12} {:>12}", "epoch", "objective", "time(s)");
    for s in &report.samples {
        println!("{:>8} {:>12.6} {:>12.4}", s.epoch, s.objective, s.time_s);
    }
    println!(
        "\nfinal objective {:.6} | consensus gap {:.2e} | stationarity P(X,Y,z) {:.2e}",
        report.final_objective.total(),
        report.consensus_max,
        report.stationarity
    );
    println!(
        "pushes {} | max staleness {} versions | elapsed {:.2}s",
        report.total_pushes(),
        report.max_staleness(),
        report.elapsed_s
    );
    let nnz = report.z_final.iter().filter(|v| v.abs() > 1e-8).count();
    println!("model sparsity: {nnz}/{} non-zero", report.z_final.len());
    Ok(())
}

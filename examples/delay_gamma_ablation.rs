//! E5 ablation: the γ ↔ delay interaction (paper §4 remark: "γ should be
//! increased as the maximum allowable delay T_ij increases").
//!
//! Staleness is injected with `pull_hold`: a worker refreshes its cached
//! z̃ only every `hold` iterations, so the copy it differentiates
//! against is up to `hold·p` block-versions old — a controlled,
//! deterministic violation budget for Assumption 3.  (Uniform network
//! latency alone does NOT create relative staleness: it slows every rank
//! equally; see DESIGN.md.)  For each (hold, γ) cell we run the threaded
//! runtime for a fixed iteration budget and report the final objective.
//!
//! Expected shape: the hold=1 column is insensitive to γ; as hold grows,
//! γ=0 degrades (stale pushes whipsaw z̃) while moderate γ damps the
//! staleness noise; very large γ over-damps everything.
//!
//!     cargo run --release --example delay_gamma_ablation

use std::path::Path;

use asybadmm::config::Config;
use asybadmm::coordinator::Session;
use asybadmm::data::gen_partitioned;
use asybadmm::report::write_file;

fn main() -> anyhow::Result<()> {
    let gammas = [0.0f32, 0.01, 0.1, 1.0, 4.0];
    let holds = [1usize, 8, 32, 128];

    let mut base = Config::small();
    base.epochs = 1000;
    base.log_every = 10_000;
    base.samples = 2048;
    base.rho = 1.5;

    let (ds, shards) = gen_partitioned(&base.synth_spec(), base.n_workers);
    println!(
        "gamma x pull-hold ablation: {} epochs, {} workers, final objective",
        base.epochs, base.n_workers
    );
    print!("{:>12}", "gamma\\hold");
    for h in &holds {
        print!("{:>12}", format!("hold={h}"));
    }
    println!();

    let mut csv = String::from("gamma,pull_hold,objective,max_staleness\n");
    for &g in &gammas {
        print!("{g:>12}");
        for &h in &holds {
            let mut cfg = base.clone();
            cfg.gamma = g;
            cfg.pull_hold = h;
            let r = Session::builder(&cfg).dataset(&ds, &shards).run()?;
            let obj = r.final_objective.total();
            print!("{obj:>12.6}");
            csv.push_str(&format!("{g},{h},{obj:.8},{}\n", r.max_staleness()));
        }
        println!();
    }

    write_file(Path::new("reports/delay_gamma.csv"), &csv)?;
    println!("\nwrote reports/delay_gamma.csv");
    Ok(())
}

//! Reproduce paper Fig. 2(a) (objective vs iterations) and Fig. 2(b)
//! (objective vs wall-clock) for p ∈ {1, 4, 8, 16, 32} workers.
//!
//! Same experimental semantics as `speedup_table1` (paper §5): one fixed
//! dataset regrouped per p, dense block footprint, cyclic selection,
//! "iteration" = one full cycle over the blocks.  Numerics run for real;
//! timing for Fig. 2(b) is virtual (DES, costs calibrated on the real
//! AOT artifact) — see DESIGN.md.  Writes reports/fig2a.csv
//! (workers,cycle,objective) and reports/fig2b.csv
//! (workers,time_s,objective).
//!
//!     cargo run --release --example convergence_fig2 [-- --quick]

use std::path::Path;

use asybadmm::config::{BlockSelection, Config};
use asybadmm::coordinator::{Algo, Observer, Progress, Session};
use asybadmm::data::gen_virtual_partitioned;
use asybadmm::problem::Problem;
use asybadmm::report::write_file;
use asybadmm::runtime::Manifest;
use asybadmm::sim::{calibrate_native, calibrate_xla};

/// Streams each watermark sample straight into the two Fig. 2 CSVs —
/// an `Observer` on the DES path (the objective is computed once per
/// sample and shared with the built-in sampler).
struct CsvTap<'a> {
    p: usize,
    n_blocks: usize,
    fig2a: &'a mut String,
    fig2b: &'a mut String,
}

impl Observer for CsvTap<'_> {
    fn on_sample(&mut self, s: &Progress<'_>) {
        let obj = s.objective().total();
        self.fig2a.push_str(&format!(
            "{},{:.2},{:.8}\n",
            self.p,
            s.epoch as f64 / self.n_blocks as f64,
            obj
        ));
        self.fig2b.push_str(&format!("{},{:.6},{:.8}\n", self.p, s.time_s, obj));
    }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let worker_counts = [1usize, 4, 8, 16, 32];
    let mut base = Config::default();
    base.blocks_per_worker = base.n_blocks;
    base.selection = BlockSelection::Cyclic;
    // rho sized against the local-mean block Lipschitz constants of the
    // dense-footprint workload (4L ~= 1.25; see admm::penalty).
    base.rho = 1.5;
    base.samples = if quick { 8192 } else { 65536 };
    let cycles = if quick { 30 } else { 100 };
    base.epochs = cycles * base.n_blocks;
    base.log_every = 2 * base.n_blocks; // sample every 2 cycles

    let manifest = Manifest::load(&base.artifacts_dir).ok();
    let cost = match &manifest {
        Some(m) => calibrate_xla(m, base.loss, base.block_size, base.m_chunk, base.d_pad)
            .map(|c| {
                let mut c = c.linearized();
                // Shared-tenancy compute variance of the paper's EC2 c4
                // fleet (stragglers bound time-to-k at high p).
                c.compute_jitter = 0.15;
                c
            })
            .unwrap_or_else(|_| {
                let (ds, shards) = gen_virtual_partitioned(&base.synth_spec(), 32, 4);
                calibrate_native(&ds, &shards, Problem::new(base.loss, base.lambda, base.clip))
            }),
        None => {
            let (ds, shards) = gen_virtual_partitioned(&base.synth_spec(), 32, 4);
            calibrate_native(&ds, &shards, Problem::new(base.loss, base.lambda, base.clip))
        }
    };

    let mut fig2a = String::from("workers,cycle,objective\n");
    let mut fig2b = String::from("workers,time_s,objective\n");

    println!(
        "Fig. 2 reproduction — {cycles} cycles, m={}, d={}",
        base.samples,
        base.n_blocks * base.block_size
    );
    for &p in &worker_counts {
        let mut cfg = base.clone();
        cfg.n_workers = p;
        let (ds, shards) = gen_virtual_partitioned(&cfg.synth_spec(), 32, p);
        let r = Session::builder(&cfg)
            .dataset(&ds, &shards)
            .algo(Algo::Sim(cost))
            .observer(CsvTap {
                p,
                n_blocks: base.n_blocks,
                fig2a: &mut fig2a,
                fig2b: &mut fig2b,
            })
            .run()?;
        let sx = r.sim.as_ref().expect("Algo::Sim reports sim extras");
        println!(
            "p={p:>2}: {} -> {:.6} in {:.1} virtual s ({} pushes, max queue {})",
            r.samples.first().map(|s| format!("{:.6}", s.objective)).unwrap_or_default(),
            r.final_objective.total(),
            sx.virtual_time_s,
            r.total_pushes(),
            sx.max_queue
        );
        // The observer streamed the watermark rows; append the
        // final-state row (it lives only in `samples`).
        if let Some(s) = r.samples.last() {
            fig2a.push_str(&format!(
                "{p},{:.2},{:.8}\n",
                s.epoch as f64 / base.n_blocks as f64,
                s.objective
            ));
            fig2b.push_str(&format!("{p},{:.6},{:.8}\n", s.time_s, s.objective));
        }
    }

    write_file(Path::new("reports/fig2a.csv"), &fig2a)?;
    write_file(Path::new("reports/fig2b.csv"), &fig2b)?;
    println!("wrote reports/fig2a.csv, reports/fig2b.csv");
    Ok(())
}
